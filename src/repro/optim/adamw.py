"""AdamW + global-norm clipping + cosine schedule (no external deps)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    coss = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, coss)


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / bc1, v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
