"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) hd=128 d_ff=17408
vocab=151936; per-head q/k RMSNorm, SwiGLU. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, head_dim=128,
    pad_heads=48,        # 40 -> 48 so head-TP divides the 16-wide model axis
    d_ff=17408, vocab=151936,
    rope_theta=1e6, qk_norm=True,
    mlp="swiglu", norm="rms",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, pad_heads=6)   # exercise padding in the smoke test
