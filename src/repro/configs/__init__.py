"""Architecture registry: the ten assigned configs + the paper's conv table."""
from repro.configs import (
    gemma3_12b, gemma2_27b, starcoder2_15b, qwen3_14b, phi3_vision_4b,
    hymba_1_5b, deepseek_v2_lite, mixtral_8x7b, whisper_small, mamba2_2_7b,
)
from repro.configs.paper_convs import TABLE1, BATCH_SIZES, ConvLayer

_MODULES = {
    "gemma3-12b": gemma3_12b,
    "gemma2-27b": gemma2_27b,
    "starcoder2-15b": starcoder2_15b,
    "qwen3-14b": qwen3_14b,
    "phi-3-vision-4.2b": phi3_vision_4b,
    "hymba-1.5b": hymba_1_5b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "mixtral-8x7b": mixtral_8x7b,
    "whisper-small": whisper_small,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.FULL


# long_500k applicability (DESIGN.md §4): run where a sub-quadratic layer
# majority exists; skip for pure full attention / enc-dec.
LONG_CONTEXT_OK = {
    "gemma3-12b": True,        # 5:1 local:global
    "gemma2-27b": True,        # 1:1 local:global
    "starcoder2-15b": False,   # pure full attention
    "qwen3-14b": False,        # pure full attention
    "phi-3-vision-4.2b": False,
    "hymba-1.5b": True,        # SWA + SSM
    "deepseek-v2-lite-16b": False,  # MLA is full attention
    "mixtral-8x7b": True,      # SWA
    "whisper-small": False,    # decoder ctx <= 448 by construction
    "mamba2-2.7b": True,       # SSM
}
