"""whisper-small [audio]: 12L enc + 12L dec, d=768 12H (MHA) hd=64
d_ff=3072 vocab=51865; enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, head_dim=64,
    pad_heads=16, pad_kv=16,    # 12 MHA heads -> 16 for head-TP
    d_ff=3072, vocab=51865,
    mlp="gelu", norm="ln",
    frontend="audio_stub", encdec=True, n_enc_layers=12, max_dec_len=448,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv=4,
    head_dim=16, d_ff=128, vocab=512, max_dec_len=32)
