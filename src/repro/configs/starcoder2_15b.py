"""starcoder2-15b [dense]: 40L d=6144 48H (GQA kv=4) hd=128 d_ff=24576
vocab=49152; GQA + RoPE, LayerNorm, non-gated GeLU MLP.
[arXiv:2402.19173; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, head_dim=128,
    d_ff=24576, vocab=49152,
    rope_theta=999999.0,
    mlp="gelu", norm="ln",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=3, d_model=96, n_heads=6, n_kv=2, head_dim=16,
    d_ff=192, vocab=512)
