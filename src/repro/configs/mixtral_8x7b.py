"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) hd=128 d_ff=14336
vocab=32000; 8 experts top-2 (renormalised), sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=32000,
    layer_pattern=("L",), window=4096,
    rope_theta=1e6,
    n_experts=8, n_shared=0, top_k=2, expert_dff=14336,
    renorm_topk=True,
    mlp="swiglu", norm="rms",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, window=8, n_experts=4, top_k=2, expert_dff=64)
