"""mamba2-2.7b [ssm]: 64L d=2560 attn-free, ssm_state=128 vocab=50280;
SSD (state-space duality), d_inner=5120 (expand 2), 80 heads x hd 64,
depthwise conv width 4, no FFN blocks. [arXiv:2405.21060; unverified]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv=1, head_dim=1,
    d_ff=0, vocab=50280,
    layer_pattern=("M",),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, conv_width=4,
    ssm_chunk=128,
    norm="rms",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, d_model=64, vocab=512, ssm_state=16,
    ssm_head_dim=16, ssm_chunk=8)
