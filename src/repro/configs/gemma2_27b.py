"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) hd=128 d_ff=36864
vocab=256000; local+global alternating (window 4096), attention-logit
softcap 50 / final-logit softcap 30, sandwich norms. [arXiv:2408.00118; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv=16, head_dim=128,
    d_ff=36864, vocab=256000,
    layer_pattern=("L", "G"), window=4096,
    rope_theta=1e4, softcap_attn=50.0, softcap_final=30.0,
    mlp="geglu", norm="rms", post_norm=True,
    embed_scale=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, window=8)
