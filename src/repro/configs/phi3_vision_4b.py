"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (kv=32, MHA) hd=96 d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend STUB (input_specs provides
576 precomputed patch embeddings prepended to the text sequence).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, head_dim=96,
    d_ff=8192, vocab=32064,
    rope_theta=1e4,
    mlp="swiglu", norm="rms",
    frontend="vision_stub", n_frontend_tokens=576,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512, n_frontend_tokens=8)
