"""Table I of the paper: 17 unit-stride convolutional layers from
AlexNet (A), VGG (V) and ResNet (R), each at batch sizes 32/64/128."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    C: int
    Cout: int
    H: int
    W: int
    kh: int
    kw: int
    pad: int = 1          # unit-stride, 'same'-style padding as in the nets


# name, C, C', H_i x W_i, k
TABLE1 = (
    ConvLayer("Vconv1.1", 3, 64, 224, 224, 3, 3),
    ConvLayer("Vconv1.2", 64, 64, 224, 224, 3, 3),
    ConvLayer("Vconv2.1", 64, 128, 112, 112, 3, 3),
    ConvLayer("Vconv2.2", 128, 128, 112, 112, 3, 3),
    ConvLayer("Vconv3.1", 128, 256, 56, 56, 3, 3),
    ConvLayer("Vconv3.2", 256, 256, 56, 56, 3, 3),
    ConvLayer("Vconv4.1", 256, 512, 28, 28, 3, 3),
    ConvLayer("Vconv4.2", 512, 512, 28, 28, 3, 3),
    ConvLayer("Vconv5", 512, 512, 14, 14, 3, 3),
    ConvLayer("Aconv2", 48, 128, 27, 27, 5, 5, pad=2),
    ConvLayer("Aconv3", 256, 384, 13, 13, 3, 3),
    ConvLayer("Aconv4", 192, 192, 13, 13, 3, 3),
    ConvLayer("Aconv5", 192, 128, 13, 13, 3, 3),
    ConvLayer("Rconv2.2", 64, 64, 56, 56, 3, 3),
    ConvLayer("Rconv3.2", 128, 128, 28, 28, 3, 3),
    ConvLayer("Rconv4.2", 256, 256, 14, 14, 3, 3),
    ConvLayer("Rconv5.2", 512, 512, 7, 7, 3, 3),
)

BATCH_SIZES = (32, 64, 128)
