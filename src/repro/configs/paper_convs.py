"""Table I of the paper: 17 unit-stride convolutional layers from
AlexNet (A), VGG (V) and ResNet (R), each at batch sizes 32/64/128."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    C: int
    Cout: int
    H: int
    W: int
    kh: int
    kw: int
    pad: int = 1          # unit-stride, 'same'-style padding as in the nets


# name, C, C', H_i x W_i, k
def network_convs(layers, batch, *, bias=True, activation="relu"):
    """Table-I layers -> ``NetworkConv`` specs for ``repro.conv.plan_network``.

    Each layer carries the fused conv+bias+activation epilogue the source
    nets apply (VGG/AlexNet/ResNet all follow every conv with bias+ReLU),
    so planning the network fuses the whole elementwise tail into stage 4.
    """
    from repro.conv import Epilogue, NetworkConv
    ep = Epilogue(bias=bias, activation=activation)
    return tuple(
        NetworkConv(name=l.name,
                    x_shape=(batch, l.C, l.H, l.W),
                    k_shape=(l.Cout, l.C, l.kh, l.kw),
                    padding=l.pad, epilogue=ep)
        for l in layers)


def vgg_network(batch, *, bias=True, activation="relu"):
    """The VGG conv trunk of Table I as one plannable network (the per-block
    max-pools between entries are elementwise-cheap and stay outside the
    conv plans; the Table-I geometries already reflect the pooled sizes)."""
    vgg = [l for l in TABLE1 if l.name.startswith("V")]
    return network_convs(vgg, batch, bias=bias, activation=activation)


TABLE1 = (
    ConvLayer("Vconv1.1", 3, 64, 224, 224, 3, 3),
    ConvLayer("Vconv1.2", 64, 64, 224, 224, 3, 3),
    ConvLayer("Vconv2.1", 64, 128, 112, 112, 3, 3),
    ConvLayer("Vconv2.2", 128, 128, 112, 112, 3, 3),
    ConvLayer("Vconv3.1", 128, 256, 56, 56, 3, 3),
    ConvLayer("Vconv3.2", 256, 256, 56, 56, 3, 3),
    ConvLayer("Vconv4.1", 256, 512, 28, 28, 3, 3),
    ConvLayer("Vconv4.2", 512, 512, 28, 28, 3, 3),
    ConvLayer("Vconv5", 512, 512, 14, 14, 3, 3),
    ConvLayer("Aconv2", 48, 128, 27, 27, 5, 5, pad=2),
    ConvLayer("Aconv3", 256, 384, 13, 13, 3, 3),
    ConvLayer("Aconv4", 192, 192, 13, 13, 3, 3),
    ConvLayer("Aconv5", 192, 128, 13, 13, 3, 3),
    ConvLayer("Rconv2.2", 64, 64, 56, 56, 3, 3),
    ConvLayer("Rconv3.2", 128, 128, 28, 28, 3, 3),
    ConvLayer("Rconv4.2", 256, 256, 14, 14, 3, 3),
    ConvLayer("Rconv5.2", 512, 512, 7, 7, 3, 3),
)

BATCH_SIZES = (32, 64, 128)
