"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) hd=256 d_ff=15360
vocab=262144; 5:1 local:global pattern, window 1024, qk-norm, dual RoPE
theta (1M global / 10k local), sandwich norms.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, head_dim=256,
    d_ff=15360, vocab=262144,
    layer_pattern=("L", "L", "L", "L", "L", "G"), window=1024,
    rope_theta=1e6, rope_theta_local=1e4, qk_norm=True,
    mlp="geglu", norm="rms", post_norm=True,
    embed_scale=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=6, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, window=8)
