"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H d_ff(expert)=1408
vocab=102400; MLA kv_lora=512 (rope_dim 64, nope head 128, v head 128),
2 shared + 64 routed experts top-6, first layer dense (d_ff 10944).
[arXiv:2405.04434; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=10944, vocab=102400,
    rope_theta=1e4,
    mla=True, kv_lora=512, rope_dim=64, v_head_dim=128,
    n_experts=64, n_shared=2, top_k=6, expert_dff=1408,
    renorm_topk=False, first_dense=1,
    mlp="swiglu", norm="rms",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=256, vocab=512, kv_lora=32, rope_dim=8, v_head_dim=16,
    n_experts=8, n_shared=1, top_k=2, expert_dff=32)
