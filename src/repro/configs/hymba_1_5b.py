"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) hd=64 d_ff=5504
vocab=32001, ssm_state=16; parallel attention+mamba heads in every layer,
sliding-window attention except first/middle/last (global), 128 learnable
meta tokens prepended. [arXiv:2411.13676; hf]"""
import dataclasses

from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
    pad_heads=48, pad_kv=8,     # 25H/5kv -> 48/8: head-TP over 16 chips
    d_ff=5504, vocab=32001,
    layer_pattern=("H",), window=1024, full_attn_idx=(0, 16, 31),
    rope_theta=1e4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, conv_width=4,
    n_meta_tokens=128,
    mlp="swiglu", norm="rms",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    FULL, n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512, window=8, full_attn_idx=(0, 3),
    ssm_state=8, ssm_head_dim=16, n_meta_tokens=4)
