"""Expert-parallel MoE via boundary all-to-all — the nFFT schedule reused.

The paper's insight: place data so the hot GEMM is purely local and pay a
single re-partitioning collective at the stage *boundary*. For MoE that is
exactly expert parallelism:

    tokens (sharded dp x model)  --a2a-->  expert-major buffers (local E/N)
            expert FFN: LOCAL matmuls, zero collectives (the hot stage)
    expert outputs               --a2a-->  token-major, combine at source

vs. the TP-MoE default in ``models/layers.moe_forward`` (d_ff sharded,
psum in the hot stage — the "wFFT" of MoE).

Implemented as a ``shard_map`` over (dp..., model): each rank routes its
token shard, packs fixed-capacity per-(dest-rank, local-expert) buffers,
a2a's them across the ``model`` axis, runs its local experts, and a2a's the
results back. Capacity overflow drops (standard token-choice semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig
# NOTE: repro.models.layers imports repro.parallel.act_sharding, so the
# mlp_forward import happens lazily inside moe_forward_ep to avoid a cycle.


def _ep_body(w_router, w1, w2, w3, x, *, cfg: ModelConfig, n_ranks: int,
             model_axis: str, cap: int):
    """Per-rank body. x: (Tl, d) local tokens; w1/w2/w3: (E_loc, ...) local
    experts; w_router: (d, E) replicated. Returns (Tl, d)."""
    Tl, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // n_ranks
    cdt = x.dtype

    logits = (x @ w_router.astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)              # (Tl, K)
    if cfg.renorm_topk:
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    flat_e = topi.reshape(-1)                         # (Tl*K,) global expert
    flat_t = jnp.repeat(jnp.arange(Tl), K)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(Tl * K) - starts[se]
    keep = pos < cap
    # slot within the (dest_rank, local_expert, capacity) send buffer
    slot = jnp.where(keep, se * cap + pos, E * cap)

    send = jnp.zeros((E * cap + 1, d), cdt).at[slot].set(
        x[st] * keep[:, None].astype(cdt))[:E * cap]
    send = send.reshape(n_ranks, E_loc * cap, d)
    # ---- boundary a2a #1: token-major -> expert-major --------------------
    recv = jax.lax.all_to_all(send, model_axis, 0, 0, tiled=False)
    # recv: (n_ranks_src, E_loc, cap, d) -> (E_loc, n_ranks_src*cap, d)
    recv = recv.reshape(n_ranks, E_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(E_loc, n_ranks * cap, d)

    # ---- HOT STAGE: local expert FFN, zero collectives -------------------
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("ecd,edf->ecf", recv, w1.astype(cdt))) * \
            jnp.einsum("ecd,edf->ecf", recv, w2.astype(cdt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", recv, w1.astype(cdt)),
                        approximate=True)
    eo = jnp.einsum("ecf,efd->ecd", h, w3.astype(cdt))

    # ---- boundary a2a #2: expert-major -> token-major ---------------------
    back = eo.reshape(E_loc, n_ranks, cap, d).transpose(1, 0, 2, 3) \
        .reshape(n_ranks, E_loc * cap, d)
    got = jax.lax.all_to_all(back, model_axis, 0, 0, tiled=False)
    got = got.reshape(E * cap, d)

    gathered = got[jnp.minimum(slot, E * cap - 1)]
    contrib = gathered * (sw * keep).astype(cdt)[:, None]
    return jnp.zeros((Tl, d), cdt).at[st].add(contrib)


def moe_forward_ep(p, x, cfg: ModelConfig, mesh, *, model_axis="model"):
    """Expert-parallel MoE. x: (B, S, d) global; expert weights sharded on
    the expert dim over ``model_axis``; tokens sharded (B over dp, S over
    model). Shared experts (deepseek) run as dense TP outside the a2a."""
    n_ranks = mesh.shape[model_axis]
    assert cfg.n_experts % n_ranks == 0, (cfg.n_experts, n_ranks)
    B, S, d = x.shape
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    Tl = (B // dp_size if B % dp_size == 0 else B) \
        * (S // n_ranks if S % n_ranks == 0 else S)
    cap = int(min(Tl, max(8, round(Tl * cfg.top_k / cfg.n_experts
                                   * cfg.capacity_factor))))

    body = functools.partial(_ep_body, cfg=cfg, n_ranks=n_ranks,
                             model_axis=model_axis, cap=cap)

    def wrapped(w_router, w1, w2, w3, x_loc):
        Bl, Sl, _ = x_loc.shape
        out = body(w_router, w1, w2, w3, x_loc.reshape(Bl * Sl, d))
        return out.reshape(Bl, Sl, d)

    b_ax = dp if B % dp_size == 0 else None
    s_ax = model_axis if S % n_ranks == 0 else None
    from repro.compat import shard_map
    out = shard_map(
        wrapped, mesh=mesh,
        in_specs=(P(), P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(b_ax, s_ax, None)),
        out_specs=P(b_ax, s_ax, None),
    )(p["w_gate_router"], p["w1"], p["w2"], p["w3"], x)
    if cfg.n_shared:
        from repro.models.layers import mlp_forward
        out = out + mlp_forward(p["shared"], x, cfg.mlp)
    return out
