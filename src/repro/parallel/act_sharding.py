"""Activation-sharding constraints (trace-time context).

GSPMD sharding propagation can drop the batch sharding inside
scan+checkpoint+vmap regions (observed: fully-replicated flash-attention
blocks, 86 GB/device). Production JAX frameworks pin activations with
``with_sharding_constraint`` at block boundaries; this module provides that
as a context manager so model code stays mesh-agnostic:

    with activation_sharding(mesh):
        lowered = jitted.lower(...)       # constraints baked at trace time

Model code calls ``constrain(x, kind)`` with kind one of:
    "seq"    (B, S, d)      -> P(dp, None, None)
    "logits" (B, S, V)      -> P(dp, None, "model")
    "heads"  (B, S, H, hd)  -> P(dp, None, "model"?, None)  (if H divides)
Outside the context these are identity, so tests/CPU runs are unaffected.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, *, model_axis: str = "model"):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, dp, model_axis)
    try:
        yield
    finally:
        _TLS.ctx = prev


def _spec(kind: str, x, mesh, dp, model_axis):
    n_model = mesh.shape[model_axis]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_ok = x.shape[0] % dp_size == 0
    b = dp if b_ok else None
    if kind == "seq":
        return P(b, *(None,) * (x.ndim - 1))
    if kind == "logits":
        v = model_axis if x.shape[-1] % n_model == 0 else None
        return P(b, *(None,) * (x.ndim - 2), v)
    if kind == "heads":
        h = model_axis if x.shape[2] % n_model == 0 else None
        return P(b, None, h, *(None,) * (x.ndim - 3))
    raise ValueError(kind)


def current_mesh():
    """Mesh of the active activation_sharding context (or None)."""
    ctx = getattr(_TLS, "ctx", None)
    return None if ctx is None else ctx[0]


def constrain(x, kind: str):
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, dp, model_axis = ctx
    spec = _spec(kind, x, mesh, dp, model_axis)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
