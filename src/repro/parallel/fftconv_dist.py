"""Distributed FFT-based convolution: the paper's nFFT vs. the wFFT baseline.

The NUMA mapping (DESIGN.md §2): NUMA node -> mesh device on the ``model``
axis, remote memory access -> ICI collective bytes.

nFFT (the paper's algorithm)
  * transforms are computed where the inputs already live
    (batch on ``data``, channels on ``model``),
  * one ``all_to_all`` per tensor at each stage *boundary* re-partitions the
    frequency axis P onto the ``model`` axis — the TPU analogue of the
    paper's "NUMA-aware tuple partitioning" (Fig. 4),
  * the hot CGEMM then runs with **zero collectives**: every chip multiplies
    its own P/N frequency slab (node-level), XLA tiles M x C' per chip
    (core-level), the MXU contracts (vector-level).

wFFT (baseline, Wang et al. 2020)
  * no tuple partitioning: the CGEMM contracts a channel axis that is spread
    over ``model``, so a ``psum`` (all-reduce of the whole Z) sits *inside*
    the hot stage — the analogue of the baseline's remote reads during the
    CGEMM.

Channel/batch axes are zero-padded up to mesh-axis multiples (e.g. VGG
conv1.1's C=3); padded channels multiply zeros and are sliced away.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.conv_spec import ConvSpec
from repro.core import fftconv as F
from repro.core.cgemm import cgemm


def _pad_axis(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _local_spec(spec: ConvSpec, b_loc: int, c_loc: int, co_loc: int):
    return ConvSpec(B=b_loc, C=c_loc, Cout=co_loc, H=spec.H, W=spec.W,
                    kh=spec.kh, kw=spec.kw, pad_h=spec.pad_h,
                    pad_w=spec.pad_w, delta=spec.delta)


def _nfft_local(x, k, spec: ConvSpec, n_model: int, model_axis: str,
                three_m: bool, cgemm_fn, replicate_kernel_transform=False,
                compute_dtype=None):
    """Per-device body of the nFFT schedule. x: (B_loc, C_loc, H, W),
    k: (C'_loc, C, kh, kw) -> O_loc: (B_loc, C'_loc, Ho, Wo).

    replicate_kernel_transform: compute the (cheap) kernel transform
    redundantly on every model rank and slice the local P-slab — removes
    boundary a2a #2 entirely (beyond-paper optimization, §Perf).
    compute_dtype: cast CGEMM operands (e.g. bf16; f32 accumulation).
    """
    b_loc, c_loc = x.shape[0], x.shape[1]
    co_loc, c_full = k.shape[0], k.shape[1]
    co_full = co_loc * n_model if not replicate_kernel_transform \
        else k.shape[0]

    # Stage 1: transform the local (B_loc, C_loc) slab -> D (P, M_loc, C_loc)
    sp1 = _local_spec(spec, b_loc, c_loc, co_loc)
    Dr, Di = F.input_transform(x, sp1)
    if compute_dtype is not None:
        # cast BEFORE the boundary a2a so the collective moves half the bytes
        Dr, Di = Dr.astype(compute_dtype), Di.astype(compute_dtype)
    # Boundary a2a #1 (tuple partitioning): (P, M, C_loc) -> (P/N, M, C)
    Dr = jax.lax.all_to_all(Dr, model_axis, 0, 2, tiled=True)
    Di = jax.lax.all_to_all(Di, model_axis, 0, 2, tiled=True)

    if replicate_kernel_transform:
        # Stage 2': full kernel transform on every rank, local P-slab slice.
        sp2 = _local_spec(spec, b_loc, c_full, co_full)
        Gr, Gi = F.kernel_transform(k, sp2)       # (P, C, C'_full)
        p_loc = spec.P // n_model
        idx = jax.lax.axis_index(model_axis) * p_loc
        Gr = jax.lax.dynamic_slice_in_dim(Gr, idx, p_loc, axis=0)
        Gi = jax.lax.dynamic_slice_in_dim(Gi, idx, p_loc, axis=0)
    else:
        # Stage 2: transform the local C'_loc kernels -> G (P, C, C'_loc)
        sp2 = _local_spec(spec, b_loc, c_full, co_loc)
        Gr, Gi = F.kernel_transform(k, sp2)
        # Boundary a2a #2: (P, C, C'_loc) -> (P/N, C, C')
        Gr = jax.lax.all_to_all(Gr, model_axis, 0, 2, tiled=True)
        Gi = jax.lax.all_to_all(Gi, model_axis, 0, 2, tiled=True)

    # Stage 3 (HOT): local P/N-slab complex GEMM — no collectives.
    if compute_dtype is not None:
        Gr, Gi = Gr.astype(compute_dtype), Gi.astype(compute_dtype)
    mm = cgemm_fn if cgemm_fn is not None else functools.partial(
        cgemm, three_m=three_m)
    Zr, Zi = mm(Dr, Di, Gr, Gi)                   # (P/N, M_loc, C') f32 acc
    if compute_dtype is not None:
        Zr, Zi = Zr.astype(compute_dtype), Zi.astype(compute_dtype)

    # Boundary a2a #3 (gather tuples for the inverse): -> (P, M_loc, C'/N)
    Zr = jax.lax.all_to_all(Zr, model_axis, 2, 0, tiled=True)
    Zi = jax.lax.all_to_all(Zi, model_axis, 2, 0, tiled=True)
    Zr, Zi = Zr.astype(jnp.float32), Zi.astype(jnp.float32)

    # Stage 4: local inverse transform of the C'_loc output slab. After
    # boundary a2a #3 each model rank holds a C'_full/N output-channel
    # slice in BOTH paths: the non-replicated path re-gathers the C'_loc
    # slabs it contracted, and the replicated path splits its full-C' Z
    # across ranks — so the local Cout is co_full // n_model either way.
    sp4 = _local_spec(spec, b_loc, c_full, co_full // n_model)
    return F.output_inverse(Zr, Zi, sp4)


def _wfft_local(x, k, spec: ConvSpec, n_model: int, model_axis: str,
                three_m: bool, cgemm_fn):
    """Per-device body of the wFFT baseline. x: (B_loc, C_loc, H, W),
    k: (C'_full, C_loc, kh, kw). The CGEMM contraction axis C is sharded, so
    a psum (all-reduce) lands inside the hot stage."""
    b_loc, c_loc = x.shape[0], x.shape[1]
    co_full = k.shape[0]

    sp1 = _local_spec(spec, b_loc, c_loc, co_full)
    Dr, Di = F.input_transform(x, sp1)            # (P, M_loc, C_loc)
    Gr, Gi = F.kernel_transform(k, sp1)           # (P, C_loc, C'_full)

    mm = cgemm_fn if cgemm_fn is not None else functools.partial(
        cgemm, three_m=three_m)
    Zr, Zi = mm(Dr, Di, Gr, Gi)                   # partial sums over C_loc
    # HOT-STAGE collective: all-reduce the full Z across the model axis.
    Zr = jax.lax.psum(Zr, model_axis)
    Zi = jax.lax.psum(Zi, model_axis)

    # Each model rank inverts its C'/N slice (avoids duplicate stage-4 work).
    co_loc = co_full // n_model
    idx = jax.lax.axis_index(model_axis)
    Zr = jax.lax.dynamic_slice_in_dim(Zr, idx * co_loc, co_loc, axis=2)
    Zi = jax.lax.dynamic_slice_in_dim(Zi, idx * co_loc, co_loc, axis=2)
    sp4 = _local_spec(spec, b_loc, c_loc, co_loc)
    return F.output_inverse(Zr, Zi, sp4)


def _fft_conv2d_sharded_impl(x, k, mesh, *, strategy: str = "nfft",
                             padding=0, delta: int = 16,
                             three_m: bool = True,
                             data_axis: str = "data",
                             model_axis: str = "model",
                             cgemm_fn=None,
                             replicate_kernel_transform=False,
                             compute_dtype=None):
    """Distributed FFT convolution (execution body of the sharded plans).

    Args:
      x: (B, C, H, W) global input; sharded (data, model, -, -).
      k: (C', C, kh, kw) global kernels.
      mesh: jax Mesh containing ``data_axis`` and ``model_axis``.
      strategy: 'nfft' (paper) or 'wfft' (baseline).
    Returns:
      (B, C', Ho, Wo), sharded (data, model, -, -).
    """
    if strategy not in ("nfft", "wfft"):
        raise ValueError(f"unknown strategy {strategy!r}")
    n_data = mesh.shape[data_axis]
    n_model = mesh.shape[model_axis]
    B, C, _, _ = x.shape
    Cout = k.shape[0]

    # Pad B/C/C' to mesh multiples; P must divide the model axis.
    xp = _pad_axis(_pad_axis(x, 0, n_data), 1, n_model)
    kp = _pad_axis(_pad_axis(k, 0, n_model), 1, n_model)
    spec = F.make_spec(xp.shape, kp.shape, padding, delta)
    if spec.P % n_model:
        raise ValueError(f"P={spec.P} not divisible by model axis {n_model}")

    if strategy == "nfft":
        body = functools.partial(
            _nfft_local, spec=spec, n_model=n_model, model_axis=model_axis,
            three_m=three_m, cgemm_fn=cgemm_fn,
            replicate_kernel_transform=replicate_kernel_transform,
            compute_dtype=compute_dtype)
        in_specs = (P(data_axis, model_axis, None, None),   # x: B, C sharded
                    P(None, None, None, None)               # k replicated
                    if replicate_kernel_transform else
                    P(model_axis, None, None, None))        # k: C' sharded
    else:
        body = functools.partial(_wfft_local, spec=spec, n_model=n_model,
                                 model_axis=model_axis, three_m=three_m,
                                 cgemm_fn=cgemm_fn)
        in_specs = (P(data_axis, model_axis, None, None),   # x: B, C sharded
                    P(None, model_axis, None, None))        # k: C sharded
    out_spec = P(data_axis, model_axis, None, None)

    y = shard_map(body, mesh=mesh, in_specs=in_specs,
                  out_specs=out_spec)(xp, kp)
    return y[:B, :Cout]


def fft_conv2d_sharded(x, k, mesh, *, strategy: str = "nfft",
                       padding=0, delta: int = 16, three_m: bool = True,
                       data_axis: str = "data", model_axis: str = "model",
                       cgemm_fn=None, replicate_kernel_transform=False,
                       compute_dtype=None):
    """Deprecated: use ``repro.conv.plan_conv(..., mesh=..., schedule=...)``.

    Thin shim over the plan API with the old signature and semantics.
    """
    warnings.warn(
        "fft_conv2d_sharded is deprecated; use repro.conv.plan_conv("
        "x.shape, k.shape, mesh=mesh, schedule='nfft'|'wfft') and call "
        "the plan", DeprecationWarning, stacklevel=2)
    if cgemm_fn is not None:
        # custom CGEMM closures can't be plan-cached; run the body directly
        return _fft_conv2d_sharded_impl(
            x, k, mesh, strategy=strategy, padding=padding, delta=delta,
            three_m=three_m, data_axis=data_axis, model_axis=model_axis,
            cgemm_fn=cgemm_fn,
            replicate_kernel_transform=replicate_kernel_transform,
            compute_dtype=compute_dtype)
    from repro.conv import plan_conv
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     delta=delta, backend="fft-xla", schedule=strategy,
                     mesh=mesh, three_m=three_m, data_axis=data_axis,
                     model_axis=model_axis, compute_dtype=compute_dtype,
                     replicate_kernel_transform=replicate_kernel_transform)
    return plan(x, k)
