"""Distributed FFT-based convolution: deprecated entry point.

The nFFT / wFFT schedules now live in the stage graph
(``repro.conv.stages``: ``NfftPipeline`` / ``WfftPipeline``) and are
composed by the plan engine — plan with ``repro.conv.plan_conv(...,
mesh=mesh, schedule="nfft"|"wfft")``.  The NUMA mapping is unchanged
(DESIGN.md §2): NUMA node -> mesh device on the ``model`` axis, remote
memory access -> ICI collective bytes.

nFFT (the paper's algorithm)
  * transforms are computed where the inputs already live
    (batch on ``data``, channels on ``model``),
  * one ``all_to_all`` per tensor at each stage *boundary* re-partitions the
    frequency axis P onto the ``model`` axis — the TPU analogue of the
    paper's "NUMA-aware tuple partitioning" (Fig. 4),
  * the hot CGEMM then runs with **zero collectives**.

wFFT (baseline, Wang et al. 2020)
  * no tuple partitioning: the CGEMM contracts a channel axis that is spread
    over ``model``, so a ``psum`` sits *inside* the hot stage.

This module keeps only the deprecated ``fft_conv2d_sharded`` shim.
"""
from __future__ import annotations

import warnings


def fft_conv2d_sharded(x, k, mesh, *, strategy: str = "nfft",
                       padding=0, delta: int = 16, three_m: bool = True,
                       data_axis: str = "data", model_axis: str = "model",
                       cgemm_fn=None, replicate_kernel_transform=False,
                       compute_dtype=None):
    """Deprecated: use ``repro.conv.plan_conv(..., mesh=..., schedule=...)``.

    Thin shim over the plan API with the old signature and semantics.
    """
    warnings.warn(
        "fft_conv2d_sharded is deprecated; use repro.conv.plan_conv("
        "x.shape, k.shape, mesh=mesh, schedule='nfft'|'wfft') and call "
        "the plan", DeprecationWarning, stacklevel=2)
    if strategy not in ("nfft", "wfft"):
        raise ValueError(f"unknown strategy {strategy!r}")
    from repro.conv import plan_conv
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     delta=delta, backend="fft-xla", schedule=strategy,
                     mesh=mesh, three_m=three_m, data_axis=data_axis,
                     model_axis=model_axis, compute_dtype=compute_dtype,
                     replicate_kernel_transform=replicate_kernel_transform,
                     cache=cgemm_fn is None)
    if cgemm_fn is not None:
        # custom CGEMM closures can't be plan-cached; run the stage pipeline
        # directly with the closure injected.
        from repro.conv import stages
        return stages.pipeline_for(strategy, cgemm_fn=cgemm_fn).full(
            plan, x, k)
    return plan(x, k)
