"""Distributed utilities (expert-parallel MoE).  The sharded conv entry
point lives in the plan/execute engine: ``repro.conv.plan_conv`` with a
mesh + ``schedule="nfft"``/``"wfft"``."""
from repro.parallel.ep_moe import moe_forward_ep

__all__ = ["moe_forward_ep"]
