"""Distributed schedules: nFFT (paper) / wFFT (baseline) + shared utilities."""
from repro.parallel.fftconv_dist import fft_conv2d_sharded

__all__ = ["fft_conv2d_sharded"]
from repro.parallel.ep_moe import moe_forward_ep  # noqa: E402,F401

__all__ += ["moe_forward_ep"]
