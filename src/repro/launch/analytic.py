"""Analytic FLOP / HBM-byte model per (arch x shape) cell.

Why analytic: XLA-CPU ``cost_analysis()`` counts while-loop bodies ONCE
(verified by micro-benchmark — a scan of 8 matmuls reports the FLOPs of 1),
so any scanned model (all of ours) is undercounted by the trip counts.
Collective bytes are recovered exactly by walking the compiled HLO call
graph (roofline.parse_collectives); FLOPs/bytes come from this model, which
counts *executed* work:

  * matmul FLOPs 2*m*n*k over every projection (from the config),
  * attention score+AV FLOPs with the blocks actually visited by the flash
    schedule (non-banded causal visits all blocks => the 2x causal
    overcompute is charged; banded local layers charge only the window),
  * MoE expert FLOPs include the capacity-padding waste (x capacity_factor),
  * training charges fwd + 2x bwd + 1x remat recompute = 4x forward,
  * HBM bytes: parameter traffic (incl. optimizer reads/writes), boundary
    activations under nothing_saveable remat, KV-cache read volume (the
    dominant decode term), and logits.

All numbers are GLOBAL (whole step across all chips); roofline terms divide
by (chips x per-chip rate) per §ROOFLINE.
"""
from __future__ import annotations

from repro.models.common import ModelConfig, ShapeCell

N_MODEL = 16      # model-axis width of the production mesh


def _attn_repl(cfg: ModelConfig) -> float:
    """Executed-work multiplier for attention: head padding when the padded
    count divides the model axis, else full replication over it."""
    Hp = cfg.padded_heads
    if Hp % N_MODEL == 0:
        return Hp / cfg.n_heads
    return float(N_MODEL)


def _attn_visited(cfg: ModelConfig, S: int, *, q_block=512, kv_block=512):
    """Per layer: average kv positions visited per query under the flash
    schedule, for (local, global) layers."""
    nk = max(S // kv_block, 1)
    full = nk * kv_block
    if cfg.window:
        wb = -(-(cfg.window + min(q_block, S)) // kv_block)
        local = min(nk, wb + 1) * kv_block
    else:
        local = full
    return local, full


def _layer_matmul_params(cfg: ModelConfig, kind: str, moe: bool) -> float:
    d = cfg.d_model
    p = 0.0
    if kind in ("G", "L", "H"):
        if cfg.mla:
            p += (d * (cfg.kv_lora + cfg.rope_dim)
                  + cfg.kv_lora * cfg.n_heads * (cfg.head_dim
                                                 + cfg.v_head_dim)
                  + d * cfg.n_heads * (cfg.head_dim + cfg.rope_dim)
                  + cfg.n_heads * cfg.v_head_dim * d)
        else:
            p += (d * cfg.n_heads * cfg.head_dim
                  + 2 * d * cfg.n_kv * cfg.head_dim
                  + cfg.n_heads * cfg.head_dim * d)
    if kind in ("M", "H"):
        di, N = cfg.d_inner, cfg.ssm_state
        p += d * 2 * di + 2 * d * N + d * cfg.ssm_heads + di * d
    if kind != "M" and cfg.d_ff:
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        if moe:
            # executed: top_k routed (x capacity padding) + shared
            p += mult * d * cfg.expert_dff * cfg.top_k * cfg.capacity_factor
            p += mult * d * cfg.expert_dff * cfg.n_shared
            p += d * cfg.n_experts          # router
        else:
            p += mult * d * cfg.d_ff
    return p


def _ssd_flops_per_token(cfg: ModelConfig) -> float:
    Q, N = cfg.ssm_chunk, cfg.ssm_state
    HP = cfg.d_inner
    # scores 2*Q*N + y_intra 2*Q*HP + states/y_inter ~ 4*N*HP
    return 2.0 * Q * N + 2.0 * Q * HP + 4.0 * N * HP


def analytic_costs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    d, V = cfg.d_model, cfg.vocab
    moe = cfg.n_experts > 0
    kinds = cfg.layer_kinds()
    locs = cfg.local_flags()
    f32, bf16 = 4, 2

    if cfg.encdec:
        enc_p, dec_p = cfg.encdec_split()
        if cell.kind == "train":
            enc_T, dec_T = B * S, B * cfg.max_dec_len
            mm = 2.0 * (enc_p * enc_T + dec_p * dec_T) + 2.0 * dec_T * V * d
            attn = _attn_repl(cfg) * 4.0 * B * cfg.n_heads * cfg.head_dim * (
                cfg.n_enc_layers * S * S
                + cfg.n_layers * (cfg.max_dec_len * cfg.max_dec_len / 2
                                  + cfg.max_dec_len * S))
            flops = 4.0 * (mm + attn)
            n = cfg.n_params()
            bytes_ = (12.0 * n * f32
                      + (cfg.n_enc_layers * enc_T
                         + cfg.n_layers * dec_T) * d * bf16 * 4
                      + dec_T * V * f32 * 2)
        elif cell.kind == "prefill":
            enc_T = B * S
            mm = 2.0 * (enc_p * enc_T + dec_p * B) + 2.0 * B * V * d
            attn = _attn_repl(cfg) * 4.0 * B * cfg.n_heads * cfg.head_dim * (
                cfg.n_enc_layers * S * S + cfg.n_layers * S)
            flops = mm + attn
            n = cfg.n_params()
            bytes_ = (n * bf16 + cfg.n_enc_layers * enc_T * d * bf16 * 4
                      + cfg.n_layers * enc_T * cfg.n_heads * cfg.head_dim
                      * bf16 * 2)
        else:
            mm = 2.0 * dec_p * B + 2.0 * B * V * d
            attn = _attn_repl(cfg) * 4.0 * B * cfg.n_heads * cfg.head_dim \
                * cfg.n_layers * (cfg.max_dec_len + S)
            flops = mm + attn
            n = cfg.n_params()
            cache = cfg.n_layers * B * cfg.n_kv * cfg.head_dim \
                * (cfg.max_dec_len + S) * 2 * bf16
            bytes_ = n * bf16 + cache
        return {"flops": flops, "bytes": bytes_}

    # ---- decoder-only ------------------------------------------------------
    layer_mm = [
        _layer_matmul_params(cfg, k, moe and i >= cfg.first_dense)
        for i, k in enumerate(kinds)]
    mm_params = sum(layer_mm)

    if cell.kind == "train":
        T = B * S
        mm = 2.0 * T * mm_params + 2.0 * T * V * d          # + logits
        attn = 0.0
        local_v, full_v = _attn_visited(cfg, S)
        for i, k in enumerate(kinds):
            if k in ("G", "L", "H"):
                hd_eff = (cfg.head_dim + cfg.rope_dim) if cfg.mla \
                    else cfg.head_dim
                visited = local_v if locs[i] else full_v
                attn += _attn_repl(cfg) * 4.0 * T * visited \
                    * cfg.n_heads * hd_eff
            if k in ("M", "H"):
                attn += T * _ssd_flops_per_token(cfg)
        flops = 4.0 * (mm + attn)                            # fwd+bwd+remat
        n = cfg.n_params()
        act = 4.0 * T * d * len(kinds) * bf16                # unit boundaries
        bytes_ = 12.0 * n * f32 + act + 2.0 * T * V * f32
        if moe:
            # dispatch buffers (x capacity factor), fwd+bwd
            Tk = T * cfg.top_k * cfg.capacity_factor
            bytes_ += 4.0 * Tk * d * bf16 * (len(kinds) - cfg.first_dense)
        return {"flops": flops, "bytes": bytes_}

    if cell.kind == "prefill":
        T = B * S
        mm = 2.0 * T * mm_params + 2.0 * B * V * d           # last-tok logits
        attn = 0.0
        local_v, full_v = _attn_visited(cfg, S)
        for i, k in enumerate(kinds):
            if k in ("G", "L", "H"):
                hd_eff = (cfg.head_dim + cfg.rope_dim) if cfg.mla \
                    else cfg.head_dim
                visited = local_v if locs[i] else full_v
                attn += _attn_repl(cfg) * 4.0 * T * visited \
                    * cfg.n_heads * hd_eff
            if k in ("M", "H"):
                attn += T * _ssd_flops_per_token(cfg)
        flops = mm + attn
        n = cfg.n_params()
        bytes_ = n * bf16 + 2.0 * T * d * len(kinds) * bf16 \
            + _cache_bytes(cfg, B, S)
        return {"flops": flops, "bytes": bytes_}

    # decode: one token per sequence against an S-long cache
    T = B
    mm = 2.0 * T * mm_params + 2.0 * T * V * d
    attn = 0.0
    for i, k in enumerate(kinds):
        if k in ("G", "L", "H"):
            if cfg.mla:
                # absorbed form: scores/AV run in kv_lora space
                attn += 4.0 * T * S * cfg.n_heads * cfg.kv_lora / 8
                attn += 2.0 * T * S * (cfg.kv_lora + cfg.rope_dim) \
                    * cfg.n_heads
            else:
                # the decode einsum runs over the PHYSICAL cache extent:
                # full S unless the layer keeps a ring cache
                ring = cfg.ring_local_cache and locs[i]
                eff = min(cfg.window, S) if ring else S
                attn += _attn_repl(cfg) * 4.0 * T * eff \
                    * cfg.n_heads * cfg.head_dim
        if k in ("M", "H"):
            attn += 4.0 * T * cfg.d_inner * cfg.ssm_state
    flops = mm + attn
    n = cfg.n_params() if not moe else cfg.n_active_params()
    bytes_ = n * bf16 + _cache_bytes(cfg, B, S)
    return {"flops": flops, "bytes": bytes_}


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Total KV/state cache bytes (read volume of one decode step)."""
    bf16 = 2
    total = 0.0
    locs = cfg.local_flags()
    for i, k in enumerate(cfg.layer_kinds()):
        if k in ("G", "L", "H"):
            ring = cfg.ring_local_cache and locs[i]
            S_eff = min(cfg.window, S) if ring else S
            if cfg.mla:
                total += B * S_eff * (cfg.kv_lora + cfg.rope_dim) * bf16
            else:
                total += 2.0 * B * cfg.padded_kv * S_eff * cfg.head_dim \
                    * bf16
        if k in ("M", "H"):
            total += B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
            total += 3.0 * B * (cfg.conv_width - 1) * cfg.d_inner * bf16
    return total
