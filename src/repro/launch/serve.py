"""Serving launcher: batched prefill + greedy decode, plus the FFT-conv
network serving path (whole-net planning + prepared kernels).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

    # the paper's VGG conv trunk through plan_network/prepare:
    PYTHONPATH=src python -m repro.launch.serve --convnet vgg --smoke \
        --batch 2 --gen 4

    # continuous batching: shape-bucketed dynamic batcher over per-bucket
    # prepared plans on a synthetic ragged Poisson trace
    # (repro.launch.batcher; --serve-compare A/Bs the pad-to-max and
    # re-plan-per-shape baselines and asserts the bucketed engine wins):
    PYTHONPATH=src python -m repro.launch.serve --convnet vgg --smoke \
        --serve-trace --max-batch 4 --replicas 1 --serve-compare
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.train import make_prefill_step, make_decode_step


# Table-I VGG entries chain into a sequential trunk with a 2x2 max-pool
# after each of these layers (the Table geometries already reflect it).
_VGG_POOL_AFTER = frozenset(
    {"Vconv1.2", "Vconv2.2", "Vconv3.2", "Vconv4.2", "Vconv5"})


def _vgg_scale(image):
    """Table-I VGG geometries scaled to a square ``image`` input."""
    from repro.configs.paper_convs import TABLE1
    if image % 32:
        raise SystemExit("--image must be a multiple of 32 (5 pool halvings)")
    return [dataclasses.replace(l, H=l.H * image // 224,
                                W=l.W * image // 224)
            for l in TABLE1 if l.name.startswith("V")]


def _vgg_forward(biases):
    """Prepared-network forward for the VGG trunk: chained prepared
    layers with fused bias+ReLU epilogues, 2x2 max-pool after each
    block (closure-held biases are batch-independent, so one callable
    serves every bucket)."""
    def forward(prepared, x):
        from repro.models.layers import maxpool2x2
        for name in prepared:
            x = prepared[name](x, bias=biases[name])
            if name in _VGG_POOL_AFTER:
                x = maxpool2x2(x)
        return x
    return forward


def serve_convnet(args):
    """Serve the paper's VGG conv trunk through the network planner.

    The whole net is planned once (``plan_network``), every kernel is
    transformed once per weights version (``NetworkPlan.prepare``), and each
    request batch runs through the prepared, epilogue-fused plans —
    the serving lifecycle the ROADMAP north-star targets.  A weight
    update is one invalidation sweep (new ``weights_version``).
    ``--serve-trace`` switches to the continuous-batching engine
    (``repro.launch.batcher``) on a synthetic ragged trace.
    """
    from repro.configs.paper_convs import network_convs
    from repro.conv import autotune, plan_network, prepared_cache_info

    if args.serve_trace:
        return serve_trace(args)

    image = args.image if args.image else (64 if args.smoke else 224)
    scale = _vgg_scale(image)
    layers = network_convs(scale, args.batch)
    backend = "tuned" if args.tune else args.conv_backend
    t0 = time.time()
    net = plan_network(layers, backend=backend, overlap=args.overlap)
    if args.tune:
        # the tuned planning sweep IS the cache warm-up: every distinct
        # layer geometry was measured (or served from the persistent
        # cache) before the first request executes
        print(f"autotune sweep: {time.time() - t0:.1f}s "
              f"(cache: {autotune.cache_path()})")
        for name, r in net.tuning_report().items():
            us = "cached/unmeasured" if r["us_per_call"] is None \
                else f"{r['us_per_call']:.0f}us"
            print(f"  {name}: {r['backend']}/{r['schedule']} "
                  f"bm={r['bm']} bn={r['bn']} bk={r['bk']} "
                  f"dft_bt={r['dft_bt']} overlap={r['overlap']} "
                  f"{us} [{r['source']}]")
    print(net.describe())
    if args.analyze:
        prof = net.analyze().raise_if_failed()
        t = prof.total_collectives
        print(f"plan-lint: OK — {len(prof.layers)} layers certified, "
              f"collectives/pass: all_to_all={t.get('all_to_all', 0)} "
              f"psum={t.get('psum', 0)}, "
              f"peak live ~{prof.peak_live_bytes / 1e6:.1f} MB/rank")

    rng = np.random.default_rng(args.seed)
    def init(shape, s=0.05):
        return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)
    kernels = {n: init(net[n].k_shape) for n in net}
    biases = {n: init((net[n].spec.Cout,)) for n in net}

    forward = _vgg_forward(biases)

    t0 = time.time()
    prepared = net.prepare(kernels, weights_version=0)
    t_prepare = time.time() - t0
    x = init((args.batch,) + net[net.layer_names[0]].x_shape[1:], 1.0)
    t0 = time.time()
    if args.timing == "per-request":
        # synchronized per-batch latencies: every iteration blocks, so
        # percentiles describe real request completion, not dispatch
        lats = []
        for _ in range(args.gen):
            tb = time.perf_counter()
            y = forward(prepared, x)
            jax.block_until_ready(y)
            lats.append(time.perf_counter() - tb)
    else:
        # throughput mode: async dispatch, ONE final sync — t_serve is a
        # wall-clock total and per-request latency is NOT derivable
        for _ in range(args.gen):
            y = forward(prepared, x)
        jax.block_until_ready(y)
        lats = None
    t_serve = time.time() - t0

    # weight update -> ONE invalidation sweep; transforms re-run once/layer
    kernels2 = {n: k + 0.01 for n, k in kernels.items()}
    prepared2 = net.prepare(kernels2, weights_version=1)
    jax.block_until_ready(forward(prepared2, x))
    info = prepared_cache_info()
    print(f"convnet=vgg image={image} batch={args.batch} "
          f"prepare={t_prepare*1e3:.0f}ms "
          f"serve={t_serve*1e3:.0f}ms/{args.gen} batches "
          f"(prepared cache: {info.hits} hits, {info.misses} misses, "
          f"{info.invalidations} invalidations)")
    if lats is not None:
        from repro.launch.batcher import _percentile
        print(f"per-request latency: p50={_percentile(lats, 50)*1e3:.1f}ms "
              f"p99={_percentile(lats, 99)*1e3:.1f}ms over {len(lats)} "
              "synchronized batches")
    print("output:", tuple(y.shape), float(jnp.mean(y)))
    return y


def serve_trace(args):
    """Continuous batching on a synthetic ragged Poisson trace.

    Buckets ragged request batches into padded power-of-two shapes,
    plans + prepares one network per bucket at startup, then drains the
    queue through jit-compiled per-bucket executors — zero re-planning
    or re-tracing on the hot path.  ``--serve-compare`` additionally
    replays the SAME trace through the two degenerate strategies the
    seed serve loop forced (pad everything to ``--max-batch``; re-plan
    per exact shape) and asserts the bucketed engine beats both.
    """
    from repro.configs.paper_convs import network_convs
    from repro.launch.batcher import (
        BucketPolicy, ServeEngine, run_trace, synthetic_trace)

    image = args.image if args.image else (64 if args.smoke else 224)
    scale = _vgg_scale(image)

    def make_layers(batch):
        return network_convs(scale, batch)

    rng = np.random.default_rng(args.seed)

    def init(shape, s=0.05):
        return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)

    probe = make_layers(1)
    kernels = {l.name: init(l.k_shape) for l in probe}
    biases = {l.name: init((l.k_shape[0],)) for l in probe}
    forward = _vgg_forward(biases)
    backend = "tuned" if args.tune else args.conv_backend

    policy = BucketPolicy(max_batch=args.max_batch)
    trace = synthetic_trace(n_requests=args.trace_requests,
                            max_batch=args.max_batch,
                            rate_rps=args.trace_rate or 1.0,
                            seed=args.seed)
    inputs = {}                     # one array per batch size, reused

    def make_input(batch, image_size):
        if batch not in inputs:
            inputs[batch] = init(
                (batch,) + probe[0].x_shape[1:], 1.0)
        return inputs[batch]

    modes = ("bucketed", "pad-max", "replan") if args.serve_compare \
        else ("bucketed",)
    reports = {}
    engines = {}
    for mode in modes:
        eng = ServeEngine(
            make_layers, kernels, policy=policy, forward=forward,
            replicas=args.replicas,
            window_s=args.batch_window_ms * 1e-3, mode=mode,
            # the A/B compares real completion latencies, so --serve-compare
            # forces synchronized per-batch timing
            timing="async" if (args.timing == "async"
                               and not args.serve_compare) else "per-batch",
            collect_results=False, backend=backend,
            overlap=args.overlap,
            load_plans=(args.load_plans or None) if mode == "bucketed"
            else None)
        t_start = eng.startup_s
        rep = run_trace(eng, trace, make_input=make_input,
                        realtime=args.trace_rate > 0)
        reports[mode] = rep
        engines[mode] = eng
        occ = rep["occupancy"]
        print(f"serve-trace mode={mode} [{eng.plan_source}]: "
              f"startup={t_start:.1f}s "
              f"wall={rep['wall_s']:.3f}s "
              f"tput={rep['throughput_rows_s']:.1f} rows/s "
              f"p50={rep['p50_us']/1e3:.1f}ms p99={rep['p99_us']/1e3:.1f}ms "
              f"occupancy={occ:.2f} "
              f"queue_max={rep['queue_depth_max']} "
              f"plan_misses_after_warmup="
              f"{rep['plan_cache_misses_after_warmup']}")
        for label, b in sorted(rep["buckets"].items()):
            print(f"    {label}: n={b['n_requests']} "
                  f"batches={b['n_batches']} "
                  f"p50={b['p50_us']/1e3:.1f}ms "
                  f"p99={b['p99_us']/1e3:.1f}ms occ={b['occupancy']:.2f}")
        if args.replicas > 1:
            print(f"    replica batches: {rep['replica_batches']}")
    bucketed = engines["bucketed"]
    if bucketed.nets:
        br = bucketed.bucket_report()
        print(f"buckets: {policy.batch_buckets()} x image={image} — "
              f"{br['n_layer_plans']} layer plans, "
              f"{br['n_distinct_plans']} distinct (shared-cache dedupe)")
    else:
        print(f"buckets: {policy.batch_buckets()} x image={image} — "
              f"rehydrated from plan artifact {args.load_plans}")

    if args.export_plans:
        p = bucketed.export_plans(args.export_plans)
        print(f"exported plan artifact: {p}")

    fingerprints_ok = None
    if args.load_plans and bucketed.plan_source == "aot":
        # plan-lint certificate: live re-plan of every stored config must
        # reproduce the export-time PlanProfile fingerprints (run AFTER
        # the trace so the re-plan never pollutes the hot-path miss count
        # snapshotted in the report)
        from repro.conv import export as planx
        v = planx.verify(args.load_plans)
        fingerprints_ok = v["ok"]
        rep = reports["bucketed"]
        fails = []
        if not v["ok"]:
            fails.append(f"export fingerprints diverge from a live "
                         f"re-plan: {v['mismatches']}")
        if rep["plan_cache_misses_after_warmup"] != 0:
            fails.append(
                f"AOT-loaded engine planned on the hot path: "
                f"{rep['plan_cache_misses_after_warmup']} plan-cache "
                "misses after warmup")
        if fails:
            raise SystemExit("load-plans certification FAILED:\n  "
                             + "\n  ".join(fails))
        print(f"load-plans OK: {v['n_checked']} layer fingerprints "
              "match a live re-plan, zero plan-cache misses after "
              "warmup")
    elif args.load_plans:
        print(f"load-plans: artifact fell back to live planning "
              f"(source={bucketed.plan_source})")

    if args.coldstart_out:
        import json
        rep = reports["bucketed"]
        payload = {
            "coldstart_s": bucketed.startup_s,
            "source": bucketed.plan_source,
            "plan_cache_misses_after_warmup":
                rep["plan_cache_misses_after_warmup"],
            "fingerprints_verified": fingerprints_ok,
            "n_buckets": len(policy.batch_buckets()),
            "image": image,
        }
        with open(args.coldstart_out, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"wrote cold-start report to {args.coldstart_out}")

    if args.bench_out:
        import json
        rows = engines["bucketed"].bench_rows()
        with open(args.bench_out, "w") as fh:
            json.dump(rows, fh, indent=1, sort_keys=True)
        print(f"wrote {len(rows)} serve/* bench rows to {args.bench_out}")

    if args.serve_compare:
        b, pm, rp = (reports[m] for m in
                     ("bucketed", "pad-max", "replan"))
        fails = []
        if not b["throughput_rows_s"] >= 1.05 * pm["throughput_rows_s"]:
            fails.append(
                f"bucketed throughput {b['throughput_rows_s']:.1f} rows/s "
                f"does not beat pad-max {pm['throughput_rows_s']:.1f} "
                "by >= 1.05x")
        if not b["p99_us"] <= rp["p99_us"] / 2:
            fails.append(
                f"bucketed p99 {b['p99_us']/1e3:.1f}ms not <= half of "
                f"replan p99 {rp['p99_us']/1e3:.1f}ms")
        if b["plan_cache_misses_after_warmup"] != 0:
            fails.append(
                f"bucketed engine planned on the hot path: "
                f"{b['plan_cache_misses_after_warmup']} plan-cache misses "
                "after warmup")
        tput_x = b["throughput_rows_s"] / max(pm["throughput_rows_s"],
                                              1e-9)
        print(f"serve-compare: bucketed tput {tput_x:.2f}x pad-max, p99 "
              f"{rp['p99_us']/max(b['p99_us'], 1e-9):.2f}x better than "
              "replan")
        if fails:
            raise SystemExit("serve-compare FAILED:\n  " +
                             "\n  ".join(fails))
        print("serve-compare OK: bucketed beats pad-max on throughput "
              "and replan on p99, zero plan-cache misses after warmup")
    return reports


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-14b")
    ap.add_argument("--convnet", choices=["vgg"], default=None,
                    help="serve the paper's conv trunk via plan_network "
                         "instead of an LM arch")
    # "auto" matches the planner's cost-model default, so untuned smoke
    # runs resolve per-geometry (direct for tiny layers, fft-xla past the
    # crossover) instead of forcing one backend; --tune overrides this
    # with measured per-geometry winners (backend="tuned").
    ap.add_argument("--conv-backend", default="auto")
    ap.add_argument("--serve-trace", action="store_true",
                    help="continuous batching: run the shape-bucketed "
                         "dynamic batcher (repro.launch.batcher) on a "
                         "synthetic ragged Poisson trace")
    ap.add_argument("--serve-compare", action="store_true",
                    help="with --serve-trace: replay the same trace "
                         "through the pad-to-max and re-plan-per-shape "
                         "baselines and FAIL unless the bucketed engine "
                         "beats both (throughput / p99) with zero "
                         "plan-cache misses after warmup")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="largest batch bucket (powers of two up to "
                         "this; requests above it are rejected)")
    ap.add_argument("--batch-window-ms", type=float, default=2.0,
                    help="batching window: a queued request is flushed "
                         "after waiting this long even if its bucket "
                         "is not full")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel replicas (one prepared network "
                         "per replica, round-robin dispatch; pair with "
                         "repro.launch.env emulated devices)")
    ap.add_argument("--trace-requests", type=int, default=0,
                    help="synthetic trace length (default 64, smoke 24)")
    ap.add_argument("--trace-rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests/s; 0 replays "
                         "the trace instantaneously (deterministic)")
    ap.add_argument("--timing", choices=["async", "per-request"],
                    default=None,
                    help="async: throughput mode, one final sync (per-"
                         "request latency NOT derivable); per-request: "
                         "synchronize every batch and report p50/p99. "
                         "Defaults: async for the fixed-shape loop, "
                         "per-request for --serve-trace (the SLO rows "
                         "must measure completion, not dispatch)")
    ap.add_argument("--bench-out", default="",
                    help="with --serve-trace: write the serve/* bench "
                         "rows (BENCH_conv.json schema) to this path")
    ap.add_argument("--export-plans", default="",
                    help="with --serve-trace: AOT-export every bucket's "
                         "planned+prepared network to this plan artifact "
                         "(.rpa) after the run")
    ap.add_argument("--load-plans", default="",
                    help="with --serve-trace: start the bucketed engine "
                         "from an AOT plan artifact (zero retracing) "
                         "instead of plan+prepare+compile; falls back to "
                         "live planning with a warning on mismatch")
    ap.add_argument("--coldstart-out", default="",
                    help="with --serve-trace: write a cold-start JSON "
                         "report (coldstart_s, source, plan-cache misses "
                         "after warmup, fingerprint verification)")
    ap.add_argument("--overlap", default="off",
                    help="conv sub-slab comm/compute overlap: off | "
                         "slab:<k> | auto (sharded schedules only; see "
                         "docs/conv_api.md)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune every distinct conv geometry (measured, "
                         "persistently cached) to warm the tuning cache "
                         "before serving; implies --convnet backend=tuned")
    ap.add_argument("--image", type=int, default=0,
                    help="convnet input size (default 224, smoke 64)")
    ap.add_argument("--analyze", action="store_true",
                    help="plan-lint the planned convnet (static analyzer, "
                         "repro.conv.analyze) before serving; aborts if "
                         "any structural invariant is violated")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if (args.tune or args.serve_trace) and not args.convnet:
        args.convnet = "vgg"        # conv-only flags imply the convnet path
    if not args.trace_requests:
        args.trace_requests = 24 if args.smoke else 64
    if args.timing is None:
        args.timing = "per-request" if args.serve_trace else "async"

    if args.convnet:
        return serve_convnet(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    B, Sp = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, Sp)), jnp.int32)
    max_len = Sp + args.gen + (cfg.n_meta_tokens or 0) + 8

    if cfg.encdec:
        params = WH.init_whisper_params(cfg, key)
        frames = jnp.asarray(rng.standard_normal((B, 64, cfg.d_model)),
                             jnp.float32)
        cache = WH.init_dec_cache(cfg, B, 64)
        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"frames": frames,
                                         "tokens": prompts[:, :1]}, cache)
        pos = 1
    else:
        params = LM.init_lm_params(cfg, key)
        cache = LM.init_cache(cfg, B, max_len)
        prefill = jax.jit(make_prefill_step(cfg, use_flash=False))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        pos = Sp + (cfg.n_meta_tokens or 0) \
            + (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, toks, jnp.int32(pos + i), cache)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tput = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode*1e3:.0f}ms ({tput_fmt(tput)})")
    print("sample tokens:", np.asarray(gen[0])[:16])
    return gen


def tput_fmt(x):
    return f"{x:.1f} tok/s"


if __name__ == "__main__":
    main()
