"""Serving launcher: batched prefill + greedy decode, plus the FFT-conv
network serving path (whole-net planning + prepared kernels).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

    # the paper's VGG conv trunk through plan_network/prepare_all:
    PYTHONPATH=src python -m repro.launch.serve --convnet vgg --smoke \
        --batch 2 --gen 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.train import make_prefill_step, make_decode_step


# Table-I VGG entries chain into a sequential trunk with a 2x2 max-pool
# after each of these layers (the Table geometries already reflect it).
_VGG_POOL_AFTER = frozenset(
    {"Vconv1.2", "Vconv2.2", "Vconv3.2", "Vconv4.2", "Vconv5"})


def serve_convnet(args):
    """Serve the paper's VGG conv trunk through the network planner.

    The whole net is planned once (``plan_network``), every kernel is
    transformed once per weights version (``prepare_all``), and each
    request batch runs through the prepared, epilogue-fused plans —
    the serving lifecycle the ROADMAP north-star targets.  A weight
    update is one invalidation sweep (new ``weights_version``).
    """
    from repro.configs.paper_convs import TABLE1, network_convs
    from repro.conv import autotune, plan_network, prepared_cache_info

    image = args.image if args.image else (64 if args.smoke else 224)
    if image % 32:
        raise SystemExit("--image must be a multiple of 32 (5 pool halvings)")
    scale = [dataclasses.replace(l, H=l.H * image // 224,
                                 W=l.W * image // 224)
             for l in TABLE1 if l.name.startswith("V")]
    layers = network_convs(scale, args.batch)
    backend = "tuned" if args.tune else args.conv_backend
    t0 = time.time()
    net = plan_network(layers, backend=backend, overlap=args.overlap)
    if args.tune:
        # the tuned planning sweep IS the cache warm-up: every distinct
        # layer geometry was measured (or served from the persistent
        # cache) before the first request executes
        print(f"autotune sweep: {time.time() - t0:.1f}s "
              f"(cache: {autotune.cache_path()})")
        for name, r in net.tuning_report().items():
            us = "cached/unmeasured" if r["us_per_call"] is None \
                else f"{r['us_per_call']:.0f}us"
            print(f"  {name}: {r['backend']}/{r['schedule']} "
                  f"bm={r['bm']} bn={r['bn']} bk={r['bk']} "
                  f"dft_bt={r['dft_bt']} overlap={r['overlap']} "
                  f"{us} [{r['source']}]")
    print(net.describe())
    if args.analyze:
        prof = net.analyze().raise_if_failed()
        t = prof.total_collectives
        print(f"plan-lint: OK — {len(prof.layers)} layers certified, "
              f"collectives/pass: all_to_all={t.get('all_to_all', 0)} "
              f"psum={t.get('psum', 0)}, "
              f"peak live ~{prof.peak_live_bytes / 1e6:.1f} MB/rank")

    rng = np.random.default_rng(args.seed)
    def init(shape, s=0.05):
        return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)
    kernels = {n: init(net[n].k_shape) for n in net}
    biases = {n: init((net[n].spec.Cout,)) for n in net}

    def forward(prepared, x):
        from repro.models.layers import maxpool2x2
        for name in net.layer_names:
            x = prepared[name](x, bias=biases[name])
            if name in _VGG_POOL_AFTER:
                x = maxpool2x2(x)
        return x

    t0 = time.time()
    prepared = net.prepare_all(kernels, weights_version=0)
    t_prepare = time.time() - t0
    x = init((args.batch,) + net[net.layer_names[0]].x_shape[1:], 1.0)
    t0 = time.time()
    for _ in range(args.gen):
        y = forward(prepared, x)
    jax.block_until_ready(y)
    t_serve = time.time() - t0

    # weight update -> ONE invalidation sweep; transforms re-run once/layer
    kernels2 = {n: k + 0.01 for n, k in kernels.items()}
    prepared2 = net.prepare_all(kernels2, weights_version=1)
    jax.block_until_ready(forward(prepared2, x))
    info = prepared_cache_info()
    print(f"convnet=vgg image={image} batch={args.batch} "
          f"prepare={t_prepare*1e3:.0f}ms "
          f"serve={t_serve*1e3:.0f}ms/{args.gen} batches "
          f"(prepared cache: {info.hits} hits, {info.misses} misses, "
          f"{info.invalidations} invalidations)")
    print("output:", tuple(y.shape), float(jnp.mean(y)))
    return y


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-14b")
    ap.add_argument("--convnet", choices=["vgg"], default=None,
                    help="serve the paper's conv trunk via plan_network "
                         "instead of an LM arch")
    ap.add_argument("--conv-backend", default="fft-xla")
    ap.add_argument("--overlap", default="off",
                    help="conv sub-slab comm/compute overlap: off | "
                         "slab:<k> | auto (sharded schedules only; see "
                         "docs/conv_api.md)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune every distinct conv geometry (measured, "
                         "persistently cached) to warm the tuning cache "
                         "before serving; implies --convnet backend=tuned")
    ap.add_argument("--image", type=int, default=0,
                    help="convnet input size (default 224, smoke 64)")
    ap.add_argument("--analyze", action="store_true",
                    help="plan-lint the planned convnet (static analyzer, "
                         "repro.conv.analyze) before serving; aborts if "
                         "any structural invariant is violated")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.tune and not args.convnet:
        args.convnet = "vgg"        # --tune implies the convnet path

    if args.convnet:
        return serve_convnet(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    B, Sp = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, Sp)), jnp.int32)
    max_len = Sp + args.gen + (cfg.n_meta_tokens or 0) + 8

    if cfg.encdec:
        params = WH.init_whisper_params(cfg, key)
        frames = jnp.asarray(rng.standard_normal((B, 64, cfg.d_model)),
                             jnp.float32)
        cache = WH.init_dec_cache(cfg, B, 64)
        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"frames": frames,
                                         "tokens": prompts[:, :1]}, cache)
        pos = 1
    else:
        params = LM.init_lm_params(cfg, key)
        cache = LM.init_cache(cfg, B, max_len)
        prefill = jax.jit(make_prefill_step(cfg, use_flash=False))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        pos = Sp + (cfg.n_meta_tokens or 0) \
            + (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, toks, jnp.int32(pos + i), cache)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tput = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode*1e3:.0f}ms ({tput_fmt(tput)})")
    print("sample tokens:", np.asarray(gen[0])[:16])
    return gen


def tput_fmt(x):
    return f"{x:.1f} tok/s"


if __name__ == "__main__":
    main()
