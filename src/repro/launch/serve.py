"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.train import make_prefill_step, make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    B, Sp = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, Sp)), jnp.int32)
    max_len = Sp + args.gen + (cfg.n_meta_tokens or 0) + 8

    if cfg.encdec:
        params = WH.init_whisper_params(cfg, key)
        frames = jnp.asarray(rng.standard_normal((B, 64, cfg.d_model)),
                             jnp.float32)
        cache = WH.init_dec_cache(cfg, B, 64)
        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"frames": frames,
                                         "tokens": prompts[:, :1]}, cache)
        pos = 1
    else:
        params = LM.init_lm_params(cfg, key)
        cache = LM.init_cache(cfg, B, max_len)
        prefill = jax.jit(make_prefill_step(cfg, use_flash=False))
        decode = jax.jit(make_decode_step(cfg))
        t0 = time.time()
        logits, cache = prefill(params, {"tokens": prompts}, cache)
        pos = Sp + (cfg.n_meta_tokens or 0) \
            + (cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, toks, jnp.int32(pos + i), cache)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tput = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode*1e3:.0f}ms ({tput_fmt(tput)})")
    print("sample tokens:", np.asarray(gen[0])[:16])
    return gen


def tput_fmt(x):
    return f"{x:.1f} tok/s"


if __name__ == "__main__":
    main()
