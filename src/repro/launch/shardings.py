"""Sharding rules: param / optimizer / batch / cache PartitionSpecs.

TP ("model" axis) placement is rule-based on the parameter's leaf name, with
divisibility guards (a dim that doesn't divide the axis is replicated).
FSDP (ZeRO-3 via GSPMD): optionally shard the largest remaining dim of every
large leaf over "data"; XLA inserts the all-gathers. Train steps use
params+opt FSDP; serve steps shard params over "model" only (bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.models.common import ModelConfig, ShapeCell

# leaf-name -> preferred model-sharded axis, counted from the END of shape
_MODEL_AXIS_RULES = {
    "embed": -2, "lm_head": -1,
    "wq": -2, "w_q": -2, "wo": -3,
    "w_uk": -2, "w_uv": -2, "w_dkv": -1,
    "w_gate": -1, "w_up": -1, "w_down": -2,
    "w1": -1, "w2": -1, "w3": -2,
    "w_z": -1, "w_x": -1, "w_out": -2, "w_dt": -1,
    "conv_x": -1, "out_norm": -1,
}
_REPLICATED = {"w_kr", "w_gate_router", "w_B", "w_C", "conv_B", "conv_C",
               "A_log", "D", "dt_bias", "gamma", "beta", "q_norm", "k_norm",
               "meta_tokens", "dec_posemb", "attn_norm", "mamba_norm",
               "step"}
_FSDP_MIN_SIZE = 1 << 16


def _leaf_name(path):
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _leaf_spec(name, shape, cfg: ModelConfig, n_model: int, n_data: int,
               model_axis: str, fsdp: bool):
    ndim = len(shape)
    axes = [None] * ndim
    if name in ("wk", "wv"):
        # GQA: shard kv heads only when they divide the axis. NEVER shard
        # head_dim — that would turn every score einsum into a psum.
        if shape[-2] % n_model == 0:
            axes[-2] = model_axis
    elif name in ("wq", "w_q", "wo", "w_uk", "w_uv"):
        # head-TP only when the (padded) head count divides the axis
        ax = _MODEL_AXIS_RULES[name]
        if shape[ax] % n_model == 0:
            axes[ax] = model_axis
    elif name in _MODEL_AXIS_RULES and name not in _REPLICATED:
        ax = _MODEL_AXIS_RULES[name]
        if ndim >= -ax and shape[ax] % n_model == 0:
            axes[ax] = model_axis
    if fsdp:
        size = 1
        for s in shape:
            size *= s
        if size >= _FSDP_MIN_SIZE:
            # largest unassigned dim divisible by the data axis
            cands = [(shape[i], i) for i in range(ndim)
                     if axes[i] is None and shape[i] % n_data == 0]
            if cands:
                _, i = max(cands)
                axes[i] = "data"
    return P(*axes)


def param_specs(cfg: ModelConfig, params_struct, mesh, *, fsdp: bool):
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]

    def spec_of(path, leaf):
        name = _leaf_name(path)
        if name in _REPLICATED:
            return P()
        return _leaf_spec(name, leaf.shape, cfg, n_model, n_data, "model",
                          fsdp)

    return jax.tree_util.tree_map_with_path(spec_of, params_struct)


def opt_specs(pspecs):
    """Optimizer state mirrors the parameter sharding (mu/nu)."""
    return {"mu": pspecs, "nu": pspecs, "step": P()}


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if cell.global_batch % _axes_size(mesh, dp) == 0 else ()
    dp_spec = dp if dp else None
    if cell.kind == "train":
        if cfg.encdec:
            return {"frames": P(dp_spec, None, None),
                    "tokens": P(dp_spec, None), "labels": P(dp_spec, None)}
        out = {"tokens": P(dp_spec, None), "labels": P(dp_spec, None)}
        if cfg.frontend == "vision_stub":
            out["img_embeds"] = P(dp_spec, None, None)
        return out
    if cell.kind == "prefill":
        if cfg.encdec:
            return {"frames": P(dp_spec, None, None),
                    "tokens": P(dp_spec, None)}
        out = {"tokens": P(dp_spec, None)}
        if cfg.frontend == "vision_stub":
            out["img_embeds"] = P(dp_spec, None, None)
        return out
    return {"tokens": P(dp_spec, None)}          # decode


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """PartitionSpecs matching the init_cache / init_dec_cache pytree.
    Per-unit-position entries can have different sequence extents (ring
    caches), so divisibility checks use each entry's own length."""
    n_model = mesh.shape["model"]
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b_ok = cell.global_batch % _axes_size(mesh, dp) == 0
    b_spec = dp if b_ok else None

    def _seq_spec(seq_len):
        # long-context (tiny batch): shard the seq dim over the DP domain
        return dp if (not b_ok and seq_len % _axes_size(mesh, dp) == 0) \
            else None

    def attn_kv(lead, seq_len):
        # kv heads on "model" when they divide; otherwise put "model" on the
        # sequence dim (flash-decoding-style KV sequence sharding). Never on
        # head_dim (that would psum every score einsum).
        seq_spec = _seq_spec(seq_len)
        if cfg.padded_kv % n_model == 0:
            h_ax, s_ax = "model", seq_spec
        else:
            h_ax = None
            s_ax = (seq_spec + ("model",) if seq_spec
                    else "model") if seq_len % n_model == 0 else seq_spec
        return P(*lead, b_spec, h_ax, s_ax, None)

    def kind_specs(kind, lead, seq_len):
        seq_spec = _seq_spec(seq_len)
        c = {}
        if kind in ("G", "L", "H"):
            if cfg.mla:
                # HILLCLIMB (deepseek decode_32k, EXPERIMENTS §Perf): latent-
                # sharded c_kv makes every score einsum psum a (B,H,S) tensor
                # (453 MB/step). Sharding the SEQ dim instead (flash-decoding
                # style) keeps scores local; only the tiny softmax stats and
                # the (B,H,lora) output psum cross chips.
                if seq_len % n_model == 0:
                    s_ax = (seq_spec + ("model",)) if seq_spec else "model"
                    c["c_kv"] = P(*lead, b_spec, s_ax, None)
                    c["k_rope"] = P(*lead, b_spec, s_ax, None)
                else:
                    l_ax = "model" if cfg.kv_lora % n_model == 0 else None
                    c["c_kv"] = P(*lead, b_spec, seq_spec, l_ax)
                    c["k_rope"] = P(*lead, b_spec, seq_spec, None)
            else:
                c["k"] = attn_kv(lead, seq_len)
                c["v"] = attn_kv(lead, seq_len)
        if kind in ("M", "H"):
            if cfg.ssm_heads % n_model == 0:
                h_ax, p_ax = "model", None
            elif cfg.ssm_head_dim % n_model == 0:
                h_ax, p_ax = None, "model"
            else:
                h_ax = p_ax = None
            c["ssm"] = P(*lead, b_spec, h_ax, p_ax, None)
            di_ax = "model" if cfg.d_inner % n_model == 0 else None
            c["conv_x"] = P(*lead, b_spec, None, di_ax)
            c["conv_B"] = P(*lead, b_spec, None, None)
            c["conv_C"] = P(*lead, b_spec, None, None)
        return c

    if cfg.encdec:
        enc_seq = _seq_spec(cell.seq_len)
        if cfg.padded_kv % n_model == 0:      # head-padded MHA: head-TP
            kv = P(None, b_spec, "model", None, None)
            return {"k": kv, "v": kv,
                    "xk": P(None, b_spec, "model", enc_seq, None),
                    "xv": P(None, b_spec, "model", enc_seq, None)}
        self_s = "model" if cfg.max_dec_len % n_model == 0 else None
        if cell.seq_len % n_model == 0:
            x_s = (enc_seq + ("model",)) if enc_seq else "model"
        else:
            x_s = enc_seq
        kv = P(None, b_spec, None, self_s, None)
        return {"k": kv, "v": kv,
                "xk": P(None, b_spec, None, x_s, None),
                "xv": P(None, b_spec, None, x_s, None)}

    unit = cfg.layer_pattern
    locs = cfg.local_flags()[cfg.first_dense:]
    n_units = (cfg.n_layers - cfg.first_dense) // len(unit)
    uniform = all(locs[u * len(unit) + j] == locs[j]
                  for u in range(n_units) for j in range(len(unit)))
    base_len = cell.seq_len + (cfg.n_meta_tokens
                               if cell.kind == "prefill" else 0)
    out = {}
    for j, kind in enumerate(unit):
        ring = (cfg.ring_local_cache and uniform and locs[j]
                and cfg.window > 0)
        len_j = min(base_len, cfg.window) if ring else base_len
        out[f"u{j}"] = kind_specs(kind, (None,), len_j)
    kinds = cfg.layer_kinds()
    for i in range(cfg.first_dense):
        out[f"dense_{i}"] = kind_specs(kinds[i], (), base_len)
    return out


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
