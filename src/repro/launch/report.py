"""Generate the EXPERIMENTS.md roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report [--out-dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_NAMES
from repro.models.common import SHAPES


def load_all(out_dir):
    recs = {}
    for fn in os.listdir(out_dir):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                r = json.load(f)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_row(r):
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | skip | — | — | — | — | — "
                f"| — | — |")
    if r["status"] == "fail":
        return f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |"
    t = r["roofline"]
    dom = t["dominant"][:4]
    return (
        f"| {r['arch']} | {r['shape']} | ok "
        f"| {r['analytic_flops']:.2e} | {r['analytic_bytes']:.2e} "
        f"| {r['collectives']['total_bytes']:.2e} "
        f"| {t['compute_s']*1e3:.2f} / {t['memory_s']*1e3:.2f} / "
        f"{t['collective_s']*1e3:.2f} "
        f"| **{dom}** | {r['useful_flops_ratio']:.2f} "
        f"| {r.get('temp_size_in_bytes', 0)/1e9:.0f} |")


HEADER = ("| arch | shape | st | FLOPs (global) | HBM bytes | coll B/dev "
          "| comp/mem/coll (ms) | bound | useful | temp GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def table(recs, mesh):
    lines = [HEADER]
    for arch in ARCH_NAMES:
        for s in SHAPES:
            r = recs.get((arch, s.name, mesh))
            if r:
                lines.append(fmt_row(r))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    args = ap.parse_args()
    recs = load_all(os.path.abspath(args.out_dir))
    base = {k: v for k, v in recs.items() if "__" not in k[2]}
    ok = sum(1 for r in base.values() if r["status"] == "ok")
    sk = sum(1 for r in base.values() if r["status"] == "skip")
    fl = sum(1 for r in base.values() if r["status"] == "fail")
    print(f"## Dry-run summary: {ok} ok / {sk} skip / {fl} fail "
          f"({len(base)} baseline cells)\n")
    for mesh in ("pod256", "pod512"):
        n = "single-pod 16x16 (256 chips)" if mesh == "pod256" else \
            "multi-pod 2x16x16 (512 chips)"
        print(f"### Mesh {n}\n")
        print(table(recs, mesh))
        print()
    variants = sorted(k for k in recs if "__" in k[2])
    if variants:
        print("### §Perf hillclimb variants (vs the baseline rows above)\n")
        print(HEADER)
        for key in variants:
            r = dict(recs[key])
            r["shape"] = f"{r['shape']} [{r['mesh'].split('__', 1)[1]}]"
            print(fmt_row(r))
        print()


if __name__ == "__main__":
    main()
