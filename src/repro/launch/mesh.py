"""Production mesh builders. Functions, not module constants, so importing
this module never touches jax device state."""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def _mk(shape, axes):
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before any "
            "jax import")
    return make_mesh(shape, axes, devices=devs[:n])


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips (pod, data, model); ``pod`` x ``data`` is the DP domain."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(n_data: int, n_model: int):
    """Small mesh over host (CPU) devices for tests/benchmarks."""
    return _mk((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
