"""Emulated-NUMA process environment for the overlapped conv schedules.

The paper's target is a many-core ARMv8 CPU whose NUMA nodes each own a
slice of the batch/channel axes; this repo emulates that mesh on one host
by splitting the CPU into N XLA host devices.  Device-count forcing and
the scheduler flags that let XLA actually *overlap* the sub-slab boundary
collectives with the hot cgemm (``ConvPlan.overlap="slab:<k>"``) are all
``XLA_FLAGS`` — which XLA reads ONCE, at backend initialization.  They
must therefore be in the environment **before jax is imported**:

    # parent shell / CI step
    export XLA_FLAGS="$(python -m repro.launch.env --ndev 4 --print)"
    python my_script.py

    # or at the very top of an entrypoint, before ``import jax``
    from repro.launch import env
    env.apply(ndev=4)
    import jax

This module is deliberately import-light (no jax at module level) so it
can be imported to *compose* the environment without initializing the
backend it is trying to configure.  ``apply`` raises if jax was already
imported, because the flags would be silently ignored.

Flags (all verified against the pinned jax build — unknown ``XLA_FLAGS``
are fatal at init):

  ``--xla_force_host_platform_device_count=N``
      Split the host CPU into N devices: the emulated NUMA mesh that
      ``repro.launch.mesh`` / ``shard_map`` shard over.
  ``--xla_cpu_use_thunk_runtime=true``
      The thunk-based CPU runtime: collectives execute as their own
      thunks instead of inline calls, which is what makes the sub-slab
      a2a/psum of slab i+1 schedulable alongside slab i's cgemm.
  ``--xla_cpu_enable_concurrency_optimized_scheduler=true``
      Latency-hiding instruction order: XLA schedules for overlap
      (issue collectives early, sink their consumers late) instead of
      minimizing live ranges.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Optional, Tuple

_OVERLAP_FLAGS = (
    "--xla_cpu_use_thunk_runtime=true",
    "--xla_cpu_enable_concurrency_optimized_scheduler=true",
)


def xla_flags(ndev: int, *, overlap: bool = True,
              extra: Tuple[str, ...] = ()) -> str:
    """The ``XLA_FLAGS`` value for an ``ndev``-device emulated NUMA mesh.

    ``overlap=False`` drops the scheduler flags (device-count forcing
    only — the synchronous baseline for A/B timing).  ``extra`` appends
    caller flags verbatim.
    """
    ndev = int(ndev)
    if ndev < 1:
        raise ValueError(f"ndev must be >= 1, got {ndev}")
    flags = [f"--xla_force_host_platform_device_count={ndev}"]
    if overlap:
        flags.extend(_OVERLAP_FLAGS)
    flags.extend(extra)
    return " ".join(flags)


def apply(ndev: int, *, overlap: bool = True,
          extra: Tuple[str, ...] = (), env: Optional[dict] = None) -> str:
    """Install the emulated-mesh ``XLA_FLAGS`` into the process env.

    Must run before jax is imported (XLA reads the flags once, at
    backend init) — raises RuntimeError if ``jax`` is already in
    ``sys.modules``.  Existing ``XLA_FLAGS`` content is preserved
    (prepended), so user-set flags survive; a flag given twice keeps the
    last occurrence, so ours win.  Returns the value installed.
    """
    if env is None:
        if "jax" in sys.modules:
            raise RuntimeError(
                "repro.launch.env.apply() called after jax was imported: "
                "XLA_FLAGS is read once at backend init, so these flags "
                "would be silently ignored.  Call apply() before "
                "`import jax`, or export XLA_FLAGS in the parent shell "
                "(`python -m repro.launch.env --ndev N --print`).")
        env = os.environ
    value = xla_flags(ndev, overlap=overlap, extra=extra)
    prior = env.get("XLA_FLAGS", "").strip()
    if prior:
        value = f"{prior} {value}"
    env["XLA_FLAGS"] = value
    return value


def mesh_shape(ndev: int, *, model: int = 1) -> Tuple[int, int]:
    """(data, model) mesh shape over ``ndev`` emulated devices: all
    parallelism on the data (batch) axis unless ``model`` divides it
    out (``ndev=8, model=2`` -> ``(4, 2)``)."""
    ndev, model = int(ndev), int(model)
    if model < 1 or ndev % model:
        raise ValueError(f"model={model} must divide ndev={ndev}")
    return (ndev // model, model)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="emulated-NUMA XLA environment (see repro.launch.env)")
    ap.add_argument("--ndev", type=int, default=4,
                    help="emulated host device count (default 4)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="device-count forcing only; drop the "
                         "latency-hiding scheduler flags")
    ap.add_argument("--print", action="store_true", dest="print_flags",
                    help="print the XLA_FLAGS value and exit (for "
                         "`export XLA_FLAGS=$(... --print)`)")
    args = ap.parse_args(argv)
    value = xla_flags(args.ndev, overlap=not args.no_overlap)
    if args.print_flags:
        print(value)
        return 0
    # no --print: show what apply() would install, plus the mesh it implies
    print(f"XLA_FLAGS={value}")
    print(f"mesh_shape(data, model) = {mesh_shape(args.ndev)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
