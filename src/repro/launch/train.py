"""Training launcher (CPU-runnable; same code path the dry-run lowers).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised here: deterministic seekable data, AdamW + cosine,
microbatching, async atomic checkpoints, crash-resume (--resume), and a
straggler watchdog (per-step wall-time EWMA; steps slower than
``--straggler-factor`` x the EWMA are logged — on a real cluster this signal
feeds the failover controller that re-queues the step's data shard, which is
replayable because batches are pure functions of the step index)."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.data import DataConfig, lm_batch, frames_batch
from repro.optim import AdamWConfig
from repro.train import make_train_step, init_train_state
import repro.checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    # size overrides (e.g. the ~100M end-to-end training run)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--n-heads", type=int, default=0)
    ap.add_argument("--n-kv", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    head_dim=args.d_model // (args.n_heads or cfg.n_heads))
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if args.n_heads:
        over.update(n_heads=args.n_heads, pad_heads=0, pad_kv=0)
    if args.n_kv:
        over["n_kv"] = args.n_kv
    if args.d_ff:
        over["d_ff"] = args.d_ff
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_groups=1)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    params, opt = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    start_step = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (state, meta) = ckpt.restore(args.ckpt_dir, last,
                                         {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start_step = last
            print(f"resumed from step {last}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                    global_batch=args.batch, seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))

    ewma = None
    for step in range(start_step, args.steps):
        if cfg.encdec:
            batch = frames_batch(dc, step, d_model=cfg.d_model, frames=64)
            batch["tokens"] = batch["tokens"][:, :cfg.max_dec_len]
            batch["labels"] = batch["labels"][:, :cfg.max_dec_len]
        else:
            batch = lm_batch(dc, step)
        t0 = time.time()
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        straggler = " [STRAGGLER]" if dt > args.straggler_factor * ewma \
            and step > start_step + 3 else ""
        if step % 10 == 0 or step == args.steps - 1 or straggler:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(m['lr']):.2e} {dt*1e3:.0f}ms{straggler}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt})
    ckpt.wait_pending()
    print("done; final loss", loss)
    return loss


if __name__ == "__main__":
    main()
