"""Roofline-term extraction from compiled XLA artifacts.

Hardware model (TPU v5e, per §ROOFLINE):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms (seconds):
    compute    = HLO_FLOPs  / (chips * PEAK_FLOPS)
    memory     = HLO_bytes  / (chips * HBM_BW)
    collective = coll_bytes / (chips * LINK_BW)

``cost_analysis()`` of a GSPMD-partitioned executable describes the
*per-device* program, so per-device values are multiplied by the chip count
to match the formula's global convention (the two normalisations cancel —
term == per_device_value / per_chip_rate).

Collective bytes are NOT in cost_analysis: we parse the compiled HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async ``-start`` ops
counted once; ``-done`` skipped).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-reduce.5 = f32[9,32,256]{2,1,0} all-reduce(%x), ...
#       %ag = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather-start(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion|conditional)\(.*?\)[^\n]*?"
                      r"(?:to_apply|called_computations)=\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """computation name -> its text block."""
    comps, cur, buf = {}, None, []
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur, buf = m.group(1), []
        elif cur is not None:
            buf.append(line)
            if line.strip() == "}":
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _trip_count(cond_text: str) -> int:
    """Heuristic: the largest integer constant in the loop condition."""
    consts = [int(c) for c in _TRIP_RE.findall(cond_text)]
    return max(consts) if consts else 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind (result-shape proxy).

    XLA cost analysis counts while-loop bodies ONCE; the same is true of a
    naive text scan. We therefore walk the call graph: collective bytes
    found inside a while body are multiplied by the loop trip count
    (extracted from the loop condition), recursively — a collective inside
    the flash-attention scan inside the layer scan is counted
    trip_inner x trip_outer times.
    """
    comps = _split_computations(hlo_text)

    def block_stats(text):
        out = {k: 0 for k in _COLL_KINDS}
        counts = {k: 0 for k in _COLL_KINDS}
        for m in _OP_RE.finditer(text):
            shape_text, kind = m.group(1), m.group(2)
            out[kind] += _shape_bytes(shape_text)
            counts[kind] += 1
        return out, counts

    # multipliers via DFS from every root (entry = any comp not referenced)
    referenced = set()
    edges = {}              # comp -> [(child, mult)]
    for name, text in comps.items():
        ch = []
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            trip = _trip_count(comps.get(cond, ""))
            ch.append((body, trip))
            referenced.update((cond, body))
        for m in _CALL_RE.finditer(text):
            ch.append((m.group(1), 1))
            referenced.add(m.group(1))
        edges[name] = ch

    entry = [n for n in comps if n not in referenced]
    mult = {n: 0 for n in comps}

    def visit(name, m, depth=0):
        if name not in comps or depth > 12:
            return
        mult[name] = mult.get(name, 0) + m
        for child, t in edges.get(name, ()):
            visit(child, m * t, depth + 1)

    for e in (entry or list(comps)[:1]):
        visit(e, 1)

    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for name, text in comps.items():
        b, c = block_stats(text)
        m = max(mult.get(name, 0), 0)
        for k in _COLL_KINDS:
            out[k] += b[k] * m
            counts[k] += c[k] * m
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    comp = flops_per_dev / PEAK_FLOPS
    mem = bytes_per_dev / HBM_BW
    coll = coll_bytes_per_dev / LINK_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    total = max(comp, mem, coll)
    return {
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom[0],
        # fraction of roofline: how close the *dominant* term is to being
        # the only cost (1.0 == perfectly balanced on the bottleneck)
        "bound_s": total,
    }


def model_flops(n_params_active: int, tokens: int, *, train: bool) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference."""
    return (6.0 if train else 2.0) * n_params_active * tokens
