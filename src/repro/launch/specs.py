"""ShapeDtypeStruct stand-ins for every model input (dry-run: weak-type
correct, shardable, zero allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ShapeCell
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.optim import adamw_init


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Batch stand-ins for one (arch x shape) cell.

    train: {tokens, labels} (+ img_embeds for vlm; frames for audio — the
    modality frontend is a stub, so the spec IS the precomputed embedding).
    prefill: {tokens} (+ stubs); decode: {tokens} of (B, 1).
    VLM image tokens count against the context budget (tokens = S - 576);
    hymba's 128 meta tokens are architectural overhead on top of S.
    """
    B, S = cell.global_batch, cell.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if cfg.encdec:
        # seq_len scales the encoder (frame count); decoder is max_dec_len.
        if cell.kind == "train":
            return {"frames": _sd((B, S, cfg.d_model), f32),
                    "tokens": _sd((B, cfg.max_dec_len), i32),
                    "labels": _sd((B, cfg.max_dec_len), i32)}
        if cell.kind == "prefill":
            return {"frames": _sd((B, S, cfg.d_model), f32),
                    "tokens": _sd((B, 1), i32)}
        return {"tokens": _sd((B, 1), i32)}

    n_img = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
    if cell.kind == "train":
        out = {"tokens": _sd((B, S - n_img), i32),
               "labels": _sd((B, S - n_img), i32)}
    elif cell.kind == "prefill":
        out = {"tokens": _sd((B, S - n_img), i32)}
    else:
        return {"tokens": _sd((B, 1), i32)}
    if n_img:
        out["img_embeds"] = _sd((B, n_img, cfg.d_model), f32)
    return out


def param_structs(cfg: ModelConfig, *, bf16: bool = False):
    init = WH.init_whisper_params if cfg.encdec else LM.init_lm_params
    structs = jax.eval_shape(lambda k: init(cfg, k),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
    if bf16:
        structs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            structs)
    return structs


def opt_structs(params_struct):
    return jax.eval_shape(adamw_init, params_struct)


def cache_structs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.encdec:
        return jax.eval_shape(
            lambda: WH.init_dec_cache(cfg, B, S))
    if cell.kind == "prefill":
        S += cfg.n_meta_tokens          # hymba meta tokens are cached too
    return jax.eval_shape(lambda: LM.init_cache(cfg, B, S))
