"""Continuous-batching serve engine over per-bucket prepared NetworkPlans.

Production conv traffic is ragged (every client sends a different batch
size) and bursty, but FFT-conv efficiency is strongly geometry- and
batch-dependent (fbfft; Zlateski et al. 2018): the fast path is a plan
that was tuned and prepared for its exact padded shape.  This module is
the serving analogue of the paper's plan-once/execute-many NUMA pipeline:

  1. A ``BucketPolicy`` fixes a small set of padded batch shapes
     (powers of two up to ``max_batch``, optionally a few image sizes).
  2. At startup the engine plans (``plan_network``, optionally
     ``backend="tuned"``) and prepares (``NetworkPlan.prepare``) one
     network per bucket — same-geometry buckets dedupe through the
     shared plan and prepared caches — and jit-compiles one executor per
     (replica, bucket).  With ``load_plans=<artifact>`` the whole sweep
     is replaced by rehydrating an AOT plan artifact
     (``repro.conv.export``): zero plan_conv calls, zero kernel
     transforms, zero retraces at startup.  The steady state executes
     only prepared, epilogue-fused plans: zero re-planning, zero
     re-tracing on the hot path.
  3. ``submit`` enqueues requests; ``drain`` packs the FIFO queue into
     bucket batches (a batching-window/timeout knob trades latency for
     occupancy), pads to the bucket, executes on the next replica
     (round-robin), unpads per request, and records per-request latency.
  4. ``report()`` / ``bench_rows()`` emit per-bucket p50/p99,
     occupancy (padding waste) and queue-depth stats in the
     ``BENCH_conv.json`` schema, so CI gates serving SLOs.

Two reference modes exist only to measure what the bucketing buys
(``benchmarks/run.py`` and the CI serve-smoke step A/B them):

  ``mode="pad-max"``   the seed serve loop's strategy: one planned shape,
                       every request padded to ``max_batch``, no
                       coalescing (throughput baseline).
  ``mode="replan"``    plan+prepare+compile for each request's exact
                       batch size on the hot path (p99 baseline).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
import warnings
from typing import Any, Callable, Optional, Sequence


class RequestTooLarge(ValueError):
    """A request exceeds the largest configured bucket."""


# --------------------------------------------------------------------------
# Bucket policy
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The fixed set of padded batch shapes the engine prepares for.

    ``batch_buckets()`` is powers of two from ``min_batch`` up, with
    ``max_batch`` always included (``max_batch=6`` -> ``(1, 2, 4, 6)``),
    so a request of size b pads to at most 2x its own rows.
    ``image_sizes`` optionally adds a small set of (square) input sizes;
    requests are grouped per image size and never mixed in one batch.
    """
    max_batch: int
    min_batch: int = 1
    image_sizes: tuple = ()

    def __post_init__(self):
        if self.min_batch < 1 or self.max_batch < self.min_batch:
            raise ValueError(
                f"need 1 <= min_batch <= max_batch, got "
                f"min_batch={self.min_batch} max_batch={self.max_batch}")

    def batch_buckets(self) -> tuple:
        out, b = [], 1
        while b < self.max_batch:
            if b >= self.min_batch:
                out.append(b)
            b *= 2
        out.append(self.max_batch)
        return tuple(out)

    def bucket_for(self, n: int, image: Optional[int] = None) -> int:
        """Smallest bucket >= ``n`` rows (``RequestTooLarge`` above
        ``max_batch``); validates ``image`` against ``image_sizes``."""
        if n < 1:
            raise ValueError(f"request batch must be >= 1, got {n}")
        if n > self.max_batch:
            raise RequestTooLarge(
                f"request batch {n} exceeds the largest bucket "
                f"(max_batch={self.max_batch}); split the request or "
                f"raise --max-batch")
        if self.image_sizes and image not in self.image_sizes:
            raise RequestTooLarge(
                f"request image size {image} is not a configured bucket "
                f"(image_sizes={self.image_sizes})")
        for b in self.batch_buckets():
            if b >= n:
                return b
        raise AssertionError("unreachable: max_batch is always a bucket")


# --------------------------------------------------------------------------
# Requests, stats
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Request:
    rid: int
    x: Any
    t_arrival: float
    image: Optional[int] = None

    @property
    def rows(self) -> int:
        return int(self.x.shape[0])


@dataclasses.dataclass
class _BucketStats:
    latencies_s: list = dataclasses.field(default_factory=list)
    service_s: list = dataclasses.field(default_factory=list)
    n_requests: int = 0
    n_batches: int = 0
    real_rows: int = 0
    padded_rows: int = 0


def _percentile(values: Sequence[float], q: float) -> float:
    """p-th percentile (nearest-rank on the sorted sample; no numpy dep
    on the hot path)."""
    if not values:
        return float("nan")
    s = sorted(values)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[idx]


# --------------------------------------------------------------------------
# Synthetic ragged traffic
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceRequest:
    t: float                      # arrival offset from trace start (s)
    batch: int
    image: Optional[int] = None


def synthetic_trace(*, n_requests: int, max_batch: int, rate_rps: float,
                    seed: int = 0, image_sizes: tuple = ()) -> tuple:
    """Reproducible ragged Poisson trace: exponential inter-arrivals at
    ``rate_rps``, batch sizes uniform on 1..max_batch (the acceptance
    trace), optional uniform image-size choice."""
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_rps, 1e-9), n_requests)
    t = 0.0
    out = []
    for g in gaps:
        t += float(g)
        img = int(rng.choice(image_sizes)) if image_sizes else None
        out.append(TraceRequest(t=t, batch=int(rng.integers(1,
                                max_batch + 1)), image=img))
    return tuple(out)


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------

class ServeEngine:
    """Shape-bucketed dynamic batcher over per-bucket prepared plans.

    Args:
      make_layers: ``make_layers(batch)`` (or ``make_layers(batch,
        image=s)`` when the policy buckets image sizes) returning the
        ``NetworkConv`` sequence for one padded input shape.
      params: layer-name -> kernel array mapping (``prepare_all``
        contract; biases etc. ride via the ``forward`` closure).
      policy: the ``BucketPolicy``.
      forward: ``forward(prepared_net, x) -> y`` executing one padded
        batch (default: chain the layers in order, no epilogue
        operands).  Compiled once per (replica, bucket) at startup.
      replicas: data-parallel copies — one prepared state per replica
        (params are ``device_put`` round-robin onto the visible
        devices), round-robin batch dispatch.
      window_s: batching window — a queued request is flushed once it
        has waited this long even if its bucket is not full (0 = flush
        every drain).
      mode: ``"bucketed"`` (the engine) | ``"pad-max"`` | ``"replan"``
        (reference baselines, see module docstring).
      timing: ``"per-batch"`` synchronizes after every bucket execution
        so per-request latency is real; ``"async"`` only synchronizes at
        ``finish()`` (throughput mode — percentiles then measure
        dispatch, not completion, and are flagged in the report).
      weights_version: forwarded to ``NetworkPlan.prepare`` (a weight
        update is ``update_weights`` = one invalidation sweep per
        bucket, which also drops any loaded plan artifact).
      load_plans: path to an AOT plan artifact (``repro.conv.export``;
        built by ``export_plans`` or ``serve --export-plans``).  Startup
        becomes artifact-load instead of plan+prepare+compile per bucket
        per replica; on any mismatch (device kind, jax version, bucket
        set, weights version) the engine warns and builds live.
      plan_kwargs: shared ``plan_network`` knobs (backend=, mesh=, ...).
    """

    def __init__(self, make_layers: Callable, params: dict, *,
                 policy: BucketPolicy,
                 forward: Optional[Callable] = None,
                 replicas: int = 1, window_s: float = 0.0,
                 mode: str = "bucketed", timing: str = "per-batch",
                 weights_version: Any = 0, collect_results: bool = True,
                 warm: bool = True, clock: Callable = time.monotonic,
                 load_plans: Optional[str] = None,
                 **plan_kwargs):
        if mode not in ("bucketed", "pad-max", "replan"):
            raise ValueError(f"unknown mode {mode!r}")
        if timing not in ("per-batch", "async"):
            raise ValueError(f"unknown timing {timing!r}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if load_plans is not None and mode != "bucketed":
            raise ValueError("load_plans requires mode='bucketed'")
        t_startup = time.perf_counter()
        self.policy = policy
        self.mode = mode
        self.timing = timing
        self.replicas = replicas
        self.window_s = float(window_s)
        self.weights_version = weights_version
        self._make_layers = make_layers
        self._forward = forward if forward is not None else _chain_forward
        self._plan_kwargs = dict(plan_kwargs)
        self._clock = clock
        self._collect = collect_results

        self._queue: collections.deque = collections.deque()
        self._rid = itertools.count()
        self._stats: dict = collections.OrderedDict()
        self._replica_batches = [0] * replicas
        self._rr = 0
        self._pending: list = []          # async-mode in-flight batches
        self.results: dict = {}
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._queue_depth_max = 0
        self._n_rejected = 0

        self._params = _replica_params(params, replicas)

        self.nets: dict = collections.OrderedDict()
        self._exec: list = [dict() for _ in range(replicas)]
        self._bucket_x: dict = {}
        self.plan_source = "live"
        if mode != "replan":
            batches = (policy.batch_buckets() if mode == "bucketed"
                       else (policy.max_batch,))
            keys = self._bucket_keys(batches)
            if load_plans is not None:
                try:
                    self._load_buckets(load_plans, keys)
                    self.plan_source = "aot"
                except Exception as e:
                    warnings.warn(
                        f"plan artifact {load_plans!r} unusable ({e}); "
                        "falling back to live planning", stacklevel=2)
            if self.plan_source != "aot":
                for key in keys:
                    self._build_bucket(key)
        self._warm_plan_misses: Optional[int] = None
        if warm:
            self.warm()
        self.startup_s = time.perf_counter() - t_startup

    # ---- bucket construction ---------------------------------------------
    def _bucket_keys(self, batches) -> list:
        images = self.policy.image_sizes or (None,)
        return [(b, img) for img in images for b in batches]

    def _layers_for(self, key):
        b, img = key
        if img is None:
            return self._make_layers(b)
        return self._make_layers(b, image=img)

    def _build_bucket(self, key) -> None:
        """Plan + prepare + compile one padded bucket shape on every
        replica.  Same-geometry buckets dedupe through the shared plan
        cache (identical frozen plans) and the prepared cache (identical
        (plan, kernel) keys per replica)."""
        import jax
        from repro.conv.netplan import plan_network
        net = plan_network(self._layers_for(key), **self._plan_kwargs)
        self.nets[key] = net
        self._bucket_x[key] = net[net.layer_names[0]].x_shape
        fwd = self._forward
        for r in range(self.replicas):
            prepared = net.prepare(
                self._params[r], weights_version=self.weights_version)
            self._exec[r][key] = jax.jit(
                lambda x, _p=prepared: fwd(_p, x))

    def _load_buckets(self, path: str, keys) -> None:
        """Rehydrate every bucket executor from an AOT plan artifact —
        zero plan_conv calls, zero kernel transforms, zero layer
        retraces.  Any mismatch raises (the constructor catches it and
        builds live): artifact-level incompatibility, a bucket missing
        from the artifact, or a stale ``weights_version``."""
        import jax
        from repro.conv import export as planx
        arts = planx.load_network(path, on_mismatch="error")
        if isinstance(arts, planx.LoadedNetwork):
            arts = {"net": arts}
        fwd = self._forward
        for key in keys:
            label = self._label(*key)
            if label not in arts:
                raise planx.ArtifactMismatch(
                    f"artifact has no bucket {label!r} "
                    f"(has: {sorted(arts)})")
            net = arts[label]
            if net.weights_version != self.weights_version:
                raise planx.ArtifactMismatch(
                    f"artifact weights_version {net.weights_version!r} "
                    f"!= engine weights_version "
                    f"{self.weights_version!r}")
            self._bucket_x[key] = tuple(net.x_shape)
            # Native-executable layers (zero-compile rehydration) cannot
            # be traced through an outer jit — chain them eagerly; each
            # layer IS a compiled XLA module already.  Portable StableHLO
            # fallbacks compose under jit as usual.
            native = any(getattr(lc, "native", False)
                         for lc in net.layers.values())
            for r in range(self.replicas):
                if native:
                    self._exec[r][key] = lambda x, _p=net: fwd(_p, x)
                else:
                    self._exec[r][key] = jax.jit(
                        lambda x, _p=net: fwd(_p, x))

    def export_plans(self, path: str) -> str:
        """AOT-export every bucket's planned+prepared network (replica
        0's params) into one artifact keyed by the current
        ``weights_version`` — the build-once half of fleet cold-start
        (``load_plans=`` / ``serve --load-plans`` is the deploy-many
        half)."""
        if not self.nets:
            raise RuntimeError(
                "export_plans needs a live-planned bucketed engine "
                "(a loaded-artifact engine has no NetworkPlans to "
                "export; rebuild with load_plans=None)")
        from repro.conv import export as planx
        nets = collections.OrderedDict(
            (self._label(b, img), net)
            for (b, img), net in self.nets.items())
        return planx.export_network(
            nets, path, params=self._params[0],
            weights_version=self.weights_version)

    def _executor(self, key, replica):
        ex = self._exec[replica].get(key)
        if ex is None:
            if self.mode != "replan":
                raise AssertionError(f"no executor for bucket {key}")
            # the replan baseline pays plan+prepare+compile here, on the
            # hot path — that cost lands in the request latencies
            self._build_bucket(key)
            ex = self._exec[replica][key]
        return ex

    def warm(self) -> None:
        """Execute one zero batch per (replica, bucket) so every jit
        compile is paid before the first request; snapshots the plan
        cache so ``report()`` can certify zero misses after warmup."""
        import jax
        import jax.numpy as jnp
        from repro.conv.plan import plan_cache_info
        for key in self._exec[0]:
            x = jnp.zeros(self._bucket_x[key], jnp.float32)
            for r in range(self.replicas):
                jax.block_until_ready(self._exec[r][key](x))
        self._warm_plan_misses = plan_cache_info().misses

    def update_weights(self, params: dict, *, weights_version) -> None:
        """Weight update: one invalidation sweep re-preparing every
        bucket on every replica under the new version.  An engine
        started from a plan artifact drops it here (the artifact is
        keyed to the old ``weights_version``) and re-plans live —
        export_plans again to refresh the fleet."""
        self.weights_version = weights_version
        self._params = _replica_params(params, self.replicas)
        self.plan_source = "live"
        for key in list(self._exec[0]):
            self._build_bucket(key)
        self.warm()

    # ---- queueing ---------------------------------------------------------
    def submit(self, x, *, image: Optional[int] = None) -> int:
        """Enqueue one request (a batch of ``x.shape[0]`` images).
        Raises ``RequestTooLarge`` when no bucket fits it."""
        if image is None and self.policy.image_sizes:
            image = int(x.shape[-1])
        try:
            self.policy.bucket_for(int(x.shape[0]), image)  # validate early
        except RequestTooLarge:
            self._n_rejected += 1
            raise
        now = self._clock()
        if self._t_first_submit is None:
            self._t_first_submit = now
        rid = next(self._rid)
        self._queue.append(_Request(rid=rid, x=x, t_arrival=now,
                                    image=image))
        self._queue_depth_max = max(self._queue_depth_max,
                                    len(self._queue))
        return rid

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def _form_batch(self, *, force: bool) -> Optional[list]:
        """FIFO-pack the queue head into one bucket batch.  The batch
        launches when it fills ``max_batch`` rows, when the oldest
        request has waited out the batching window, or on ``force``
        (end-of-trace flush).  Baseline modes never coalesce."""
        if not self._queue:
            return None
        head = self._queue[0]
        if self.mode != "bucketed":
            self._queue.popleft()
            return [head]
        take, rows = [], 0
        skipped = collections.deque()
        while self._queue:
            r = self._queue.popleft()
            if r.image != head.image:
                skipped.append(r)
                continue
            if rows + r.rows > self.policy.max_batch:
                skipped.append(r)
                break
            take.append(r)
            rows += r.rows
        while self._queue:
            skipped.append(self._queue.popleft())
        self._queue = skipped
        full = rows >= self.policy.max_batch
        waited = (self._clock() - head.t_arrival) >= self.window_s
        if full or waited or force:
            return take
        # window still open and the bucket is not full: requeue in order
        for r in reversed(take):
            self._queue.appendleft(r)
        return None

    # ---- execution --------------------------------------------------------
    def drain(self, *, force: bool = False) -> int:
        """Run formable batches until the queue empties or the batching
        window holds the remainder back; returns batches executed.
        Draining an empty queue is a no-op returning 0."""
        n = 0
        while True:
            reqs = self._form_batch(force=force)
            if reqs is None:
                return n
            self._run_batch(reqs)
            n += 1

    def _label(self, bucket: int, image) -> str:
        return f"b{bucket}" if image is None else f"b{bucket}i{image}"

    def _run_batch(self, reqs: list) -> None:
        import jax
        import jax.numpy as jnp
        rows = sum(r.rows for r in reqs)
        image = reqs[0].image
        if self.mode == "pad-max":
            bucket = self.policy.max_batch
        elif self.mode == "replan":
            bucket = rows                      # exact shape, no padding
        else:
            bucket = self.policy.bucket_for(rows, image)
        key = (bucket, image)
        replica = self._rr
        self._rr = (self._rr + 1) % self.replicas
        t0 = self._clock()
        ex = self._executor(key, replica)      # replan: builds here
        parts = [r.x for r in reqs]
        if rows < bucket:
            parts.append(jnp.zeros((bucket - rows,) + tuple(
                reqs[0].x.shape[1:]), reqs[0].x.dtype))
        xpad = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        y = ex(xpad)
        if self.timing == "per-batch":
            jax.block_until_ready(y)
        t1 = self._clock()
        self._replica_batches[replica] += 1
        self._t_last_done = t1
        st = self._stats.setdefault(self._label(bucket, image),
                                    _BucketStats())
        st.n_batches += 1
        st.real_rows += rows
        st.padded_rows += bucket
        st.service_s.append(t1 - t0)
        off = 0
        for r in reqs:
            st.n_requests += 1
            st.latencies_s.append(t1 - r.t_arrival)
            if self._collect:
                self.results[r.rid] = y[off:off + r.rows]
            off += r.rows
        if self.timing == "async":
            self._pending.append(y)

    def finish(self) -> None:
        """Block until every dispatched batch completed (async mode);
        closes the wall-clock window the throughput is computed over."""
        import jax
        if self._pending:
            jax.block_until_ready(self._pending)
            self._pending = []
            self._t_last_done = self._clock()

    # ---- accounting -------------------------------------------------------
    def report(self) -> dict:
        """Per-bucket latency percentiles + occupancy and engine-wide
        throughput/queue/cache stats (all derived from per-request
        accounting — nothing here times a bare dispatch unless
        ``timing="async"``, which the report flags)."""
        from repro.conv.plan import plan_cache_info
        buckets = {}
        all_lat: list = []
        total_req = total_real = total_padded = 0
        for label, st in self._stats.items():
            all_lat.extend(st.latencies_s)
            buckets[label] = {
                "p50_us": _percentile(st.latencies_s, 50) * 1e6,
                "p99_us": _percentile(st.latencies_s, 99) * 1e6,
                "service_p50_us": _percentile(st.service_s, 50) * 1e6,
                "n_requests": st.n_requests,
                "n_batches": st.n_batches,
                "occupancy": (st.real_rows / st.padded_rows
                              if st.padded_rows else float("nan")),
            }
            total_req += st.n_requests
            total_real += st.real_rows
            total_padded += st.padded_rows
        wall = None
        if self._t_first_submit is not None and \
                self._t_last_done is not None:
            wall = max(self._t_last_done - self._t_first_submit, 1e-9)
        misses_after_warm = None
        if self._warm_plan_misses is not None:
            misses_after_warm = (plan_cache_info().misses
                                 - self._warm_plan_misses)
        return {
            "mode": self.mode,
            "timing": self.timing,
            "replicas": self.replicas,
            "window_s": self.window_s,
            "buckets": buckets,
            "p50_us": _percentile(all_lat, 50) * 1e6,
            "p99_us": _percentile(all_lat, 99) * 1e6,
            "n_requests": total_req,
            "n_rejected": self._n_rejected,
            "real_rows": total_real,
            "padded_rows": total_padded,
            "occupancy": (total_real / total_padded if total_padded
                          else float("nan")),
            "wall_s": wall,
            "throughput_rows_s": (total_real / wall if wall else None),
            "queue_depth_max": self._queue_depth_max,
            "replica_batches": list(self._replica_batches),
            "plan_cache_misses_after_warmup": misses_after_warm,
            "startup_s": self.startup_s,
            "plan_source": self.plan_source,
        }

    def bucket_report(self) -> dict:
        """Cross-bucket plan-dedupe/cost summary
        (``BucketedNetworkPlan.report`` semantics over this engine's
        buckets, keyed by bucket label).  Unavailable on an engine
        started from a plan artifact (no live ``NetworkPlan`` objects)."""
        if not self.nets:
            raise RuntimeError(
                "bucket_report needs live-planned buckets (this engine "
                "loaded an AOT plan artifact)")
        from repro.conv.netplan import _bucket_report
        nets = {self._label(b, img): net
                for (b, img), net in self.nets.items()}
        return _bucket_report(nets)

    def bench_rows(self, prefix: str = "serve") -> dict:
        """The report in ``BENCH_conv.json`` schema: one row per bucket
        per metric (``serve/<bucket>/{p50,p99,occupancy}``), percentiles
        riding the entry's tolerated ``percentiles`` field so the
        baseline gate can hold serving SLOs."""
        rep = self.report()
        config = {"mode": rep["mode"], "replicas": rep["replicas"],
                  "window_s": rep["window_s"], "timing": rep["timing"]}
        rows = {}
        for label, b in rep["buckets"].items():
            pcts = {"p50": b["p50_us"], "p99": b["p99_us"]}
            meta = dict(config, n_requests=b["n_requests"],
                        n_batches=b["n_batches"])
            rows[f"{prefix}/{label}/p50"] = {
                "us_per_call": b["p50_us"], "percentiles": pcts,
                "config": meta}
            rows[f"{prefix}/{label}/p99"] = {
                "us_per_call": b["p99_us"], "percentiles": pcts,
                "config": meta}
            # occupancy is a 0..1 ratio riding the same schema (the
            # gate's min-us floor keeps it out of ratio comparisons)
            rows[f"{prefix}/{label}/occupancy"] = {
                "us_per_call": b["occupancy"], "config": meta}
        return rows


def _replica_params(params: dict, replicas: int) -> list:
    """One param pytree per replica.  With one replica the caller's
    arrays are used as-is, so repeat engine builds over the same params
    dedupe through the prepared cache (keyed ``(plan, id(kernel))``);
    multiple replicas get ``device_put`` copies round-robin over the
    visible devices — distinct arrays, so each replica owns its own
    prepared state (and its own device under an emulated mesh)."""
    if replicas == 1:
        return [dict(params)]
    import jax
    devices = jax.devices()
    return [jax.device_put(dict(params), devices[r % len(devices)])
            for r in range(replicas)]


def _chain_forward(prepared, x):
    """Default forward: the prepared layers chained in declaration
    order, no epilogue operands (nets whose plans fuse bias/residual
    pass a custom ``forward`` closing over those arrays)."""
    for name in prepared:
        x = prepared[name](x)
    return x


# --------------------------------------------------------------------------
# Trace replay
# --------------------------------------------------------------------------

def run_trace(engine: ServeEngine, trace: Sequence[TraceRequest], *,
              make_input: Callable, realtime: bool = True,
              sleep: Callable = time.sleep) -> dict:
    """Replay a trace through the engine; returns ``engine.report()``.

    ``realtime=True`` sleeps each request to its arrival offset and
    drains between arrivals — latencies reflect the trace's offered
    rate.  ``realtime=False`` is the deterministic burst replay: the
    whole trace is submitted up front and then drained, so every
    strategy faces the IDENTICAL backlog (the fair A/B for the
    pad-max/replan baselines — no sleeps, no rate tuning).
    ``make_input(batch, image) -> x``."""
    t0 = engine._clock()
    for tr in trace:
        if realtime:
            dt = tr.t - (engine._clock() - t0)
            if dt > 0:
                sleep(dt)
        engine.submit(make_input(tr.batch, tr.image), image=tr.image)
        if realtime:
            engine.drain()
    engine.drain(force=True)
    engine.finish()
    return engine.report()
