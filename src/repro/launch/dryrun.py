import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below is ordinary code.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_NAMES, get_config, LONG_CONTEXT_OK  # noqa: E402
from repro.models.common import SHAPES                             # noqa: E402
from repro.launch.mesh import make_production_mesh                 # noqa: E402
from repro.launch import shardings as SH                           # noqa: E402
from repro.launch import specs as SP                               # noqa: E402
from repro.launch.roofline import (parse_collectives, roofline_terms,
                                   model_flops)                    # noqa: E402
from repro.launch.analytic import analytic_costs                   # noqa: E402
from repro.train import (make_train_step, make_prefill_step,
                         make_decode_step)                         # noqa: E402
from repro.parallel.act_sharding import activation_sharding        # noqa: E402
from repro.optim import AdamWConfig                                # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis, and
cache per-cell JSON for the roofline table (EXPERIMENTS.md §Dry-run).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shape_by_name(name):
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = ""):
    """Returns (jitted_fn, args, meta) for one cell. variant: optional
    hillclimb configuration tag (EXPERIMENTS §Perf), e.g. 'ring'."""
    cell = _shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dp = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    cfg = get_config(arch)
    if cfg.n_experts and cell.global_batch * cell.seq_len % n_dp == 0:
        cfg = dataclasses.replace(cfg, moe_groups=n_dp)
    if variant == "ring":
        cfg = dataclasses.replace(cfg, ring_local_cache=True)
    elif variant == "ep":
        cfg = dataclasses.replace(cfg, moe_ep=True)

    bspec = SH.named(mesh, SH.batch_specs(cfg, cell, mesh))
    batch = SP.input_specs(cfg, cell)

    if cell.kind == "train":
        pstr = SP.param_structs(cfg)
        ostr = SP.opt_structs(pstr)
        pspec = SH.named(mesh, SH.param_specs(cfg, pstr, mesh, fsdp=True))
        ospec = {"mu": pspec, "nu": pspec,
                 "step": SH.named(mesh, jax.sharding.PartitionSpec())}
        fn = make_train_step(cfg, AdamWConfig(), use_flash=True,
                             grad_bf16=True)
        jfn = jax.jit(fn, in_shardings=(pspec, ospec, bspec),
                      out_shardings=(pspec, ospec, None))
        args = (pstr, ostr, batch)
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        pstr = SP.param_structs(cfg, bf16=True)
        cstr = SP.cache_structs(cfg, cell)
        pspec = SH.named(mesh, SH.param_specs(cfg, pstr, mesh, fsdp=False))
        cspec = SH.named(mesh, SH.cache_specs(cfg, cell, mesh))
        fn = make_prefill_step(cfg)
        jfn = jax.jit(fn, in_shardings=(pspec, bspec, cspec),
                      out_shardings=(None, cspec))
        args = (pstr, batch, cstr)
        tokens = cell.global_batch * cell.seq_len
    else:                                       # decode
        pstr = SP.param_structs(cfg, bf16=True)
        cstr = SP.cache_structs(cfg, cell)
        pspec = SH.named(mesh, SH.param_specs(cfg, pstr, mesh, fsdp=False))
        cspec = SH.named(mesh, SH.cache_specs(cfg, cell, mesh))
        fn = make_decode_step(cfg)
        jfn = jax.jit(fn, in_shardings=(pspec, bspec["tokens"], None, cspec),
                      out_shardings=(None, cspec))
        args = (pstr, batch["tokens"], jax.ShapeDtypeStruct((), jnp.int32),
                cstr)
        tokens = cell.global_batch                 # one new token per seq
    meta = {"cfg": cfg, "cell": cell, "mesh": mesh, "tokens": tokens}
    return jfn, args, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, verbose: bool = True, variant: str = ""):
    mesh_tag = "pod512" if multi_pod else "pod256"
    if variant:
        mesh_tag = f"{mesh_tag}__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "status": "ok"}
    if shape_name == "long_500k" and not LONG_CONTEXT_OK[arch]:
        rec["status"] = "skip"
        rec["reason"] = "pure full-attention arch; see DESIGN.md §4"
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_tag}] SKIP "
                  f"({rec['reason']})")
        return rec

    try:
        t0 = time.time()
        jfn, args, meta = build_cell(arch, shape_name, multi_pod, variant)
        with activation_sharding(meta["mesh"]):
            lowered = jfn.lower(*args)      # constraints baked at trace time
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = str(mem)
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "generated_code_size_in_bytes"):
                if hasattr(mem, attr):
                    rec[attr] = int(getattr(mem, attr))
        except Exception as e:                      # CPU backend may lack it
            rec["memory_analysis"] = f"unavailable on this backend: {e}"

        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["flops_per_device"] = float(ca.get("flops", 0.0))
            rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)
            rec["flops_per_device"] = 0.0
            rec["bytes_per_device"] = 0.0

        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rec["collectives"] = coll
        rec["hlo_bytes"] = len(hlo)

        cfg, cell = meta["cfg"], meta["cell"]
        n_dev = meta["mesh"].size
        rec["n_devices"] = n_dev
        # Primary FLOPs/bytes are the analytic executed-work model (XLA-CPU
        # cost_analysis counts while bodies once — kept as a cross-check).
        ac = analytic_costs(cfg, cell)
        rec["analytic_flops"] = ac["flops"]
        rec["analytic_bytes"] = ac["bytes"]
        terms = roofline_terms(ac["flops"] / n_dev, ac["bytes"] / n_dev,
                               coll["total_bytes"])
        rec["roofline"] = terms
        if cfg.encdec:
            enc_p, dec_p = cfg.encdec_split()
            B = cell.global_batch
            f = 6.0 if cell.kind == "train" else 2.0
            if cell.kind == "train":
                mf = f * (enc_p * B * cell.seq_len
                          + dec_p * B * cfg.max_dec_len)
            elif cell.kind == "prefill":
                mf = f * (enc_p * B * cell.seq_len + dec_p * B)
            else:
                mf = f * dec_p * B
        else:
            mf = model_flops(cfg.n_active_params(), meta["tokens"],
                             train=(cell.kind == "train"))
        rec["model_flops"] = mf
        rec["useful_flops_ratio"] = (mf / ac["flops"]) if ac["flops"] else 0.0
        rec["lower_s"] = t1 - t0
        rec["compile_s"] = t2 - t1
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_tag}] OK  "
                  f"flops={ac['flops']:.3e} bytes={ac['bytes']:.3e} "
                  f"coll/dev={coll['total_bytes']:.3e}  "
                  f"dominant={terms['dominant']} "
                  f"bound={terms['bound_s']*1e3:.2f}ms "
                  f"useful={rec['useful_flops_ratio']:.2f} "
                  f"temp/dev={rec.get('temp_size_in_bytes', 0)/1e9:.1f}GB "
                  f"(compile {rec['compile_s']:.0f}s)")
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_tag}] FAIL: {rec['error']}")

    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="hillclimb config tag (e.g. 'ring')")
    ap.add_argument("--out-dir", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    if args.all:
        import subprocess
        fails = []
        for arch in ARCH_NAMES:
            for s in SHAPES:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", s.name,
                       "--out-dir", args.out_dir]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.force:
                    cmd.append("--force")
                r = subprocess.run(cmd, env=dict(os.environ))
                if r.returncode != 0:
                    fails.append((arch, s.name))
        if fails:
            print("FAILED CELLS:", fails)
            sys.exit(1)
        return

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out_dir,
                   force=args.force, variant=args.variant)
    if rec["status"] == "fail":
        sys.exit(1)


if __name__ == "__main__":
    main()
