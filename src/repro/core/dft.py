"""DFT-as-matmul: the TPU-native replacement for NEON FFT butterflies.

The paper computes 16x16 tile FFTs with hand-vectorised butterflies. A
systolic MXU hates butterfly networks but eats dense 16x16 matmuls, so we
express every (i)rfft2 of a tile as two small matrix products against
precomputed DFT matrices:

    rfft2(x)  = F_full @ x @ F_half^T            (x real, delta x delta)
    irfft2(Z) = Re( (Finv @ Z) @ Wr^T )          (Z complex, delta x delta_h)

where delta_h = delta//2 + 1 and Wr folds the Hermitian-redundant columns
back with weight 2 (columns 0 and Nyquist with weight 1).

All complex arithmetic is struct-of-arrays (separate real/imag planes);
neither the MXU nor Pallas has a native complex dtype.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def _dft_mats_np(delta: int):
    """Precompute (numpy, float64 -> float32) all DFT matrices for a tile size."""
    dh = delta // 2 + 1
    u = np.arange(delta)
    # Forward full DFT: F[u, h] = exp(-2i pi u h / delta)
    ang = -2.0 * np.pi * np.outer(u, u) / delta
    F = np.cos(ang) + 1j * np.sin(ang)
    F_half = F[:dh, :]                      # rfft over the last axis
    # Inverse full DFT (axis 0): Finv[h, u] = exp(+2i pi u h / delta) / delta
    Finv = np.conj(F).T / delta
    # Weighted inverse-rfft (last axis): x[., w] = Re(sum_v c_v Y[., v] e^{2i pi v w/delta})/delta
    # Fold weight 1 only for self-conjugate bins: DC always, Nyquist only
    # when delta is even (odd delta has no Nyquist bin — v == delta//2 there
    # still has a dropped conjugate partner and needs weight 2).
    v = np.arange(dh)
    self_conj = (v == 0) | ((delta % 2 == 0) & (v == delta // 2))
    c = np.where(self_conj, 1.0, 2.0)
    angw = 2.0 * np.pi * np.outer(np.arange(delta), v) / delta
    W = (np.cos(angw) + 1j * np.sin(angw)) * c[None, :] / delta   # (delta, dh)
    return (
        F.real.astype(np.float32), F.imag.astype(np.float32),
        F_half.real.astype(np.float32), F_half.imag.astype(np.float32),
        Finv.real.astype(np.float32), Finv.imag.astype(np.float32),
        W.real.astype(np.float32), W.imag.astype(np.float32),
    )


def dft_mats(delta: int):
    """jnp copies of all DFT matrices for tile size ``delta``."""
    return tuple(jnp.asarray(m) for m in _dft_mats_np(delta))


def rfft2_tiles(x, delta: int):
    """Batched rfft2 of real tiles via matmul.

    x: (..., delta, delta) real -> (Tr, Ti): (..., delta, delta_h).
    """
    Fr, Fi, Fhr, Fhi, *_ = dft_mats(delta)
    # A = F @ x  (x real): 2 real matmuls
    Ar = jnp.einsum("uh,...hw->...uw", Fr, x)
    Ai = jnp.einsum("uh,...hw->...uw", Fi, x)
    # T = A @ F_half^T: (Ar + iAi)(Fhr^T + iFhi^T)
    Tr = jnp.einsum("...uw,vw->...uv", Ar, Fhr) - jnp.einsum("...uw,vw->...uv", Ai, Fhi)
    Ti = jnp.einsum("...uw,vw->...uv", Ar, Fhi) + jnp.einsum("...uw,vw->...uv", Ai, Fhr)
    return Tr, Ti


def irfft2_tiles(Zr, Zi, delta: int):
    """Batched irfft2 via matmul. (Zr, Zi): (..., delta, delta_h) -> (..., delta, delta) real."""
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    # Y = Finv @ Z (complex x complex)
    Yr = jnp.einsum("hu,...uv->...hv", Fvr, Zr) - jnp.einsum("hu,...uv->...hv", Fvi, Zi)
    Yi = jnp.einsum("hu,...uv->...hv", Fvr, Zi) + jnp.einsum("hu,...uv->...hv", Fvi, Zr)
    # x = Re( Y @ W^T ) = Yr @ Wr^T - Yi @ Wi^T
    return jnp.einsum("...hv,wv->...hw", Yr, Wr) - jnp.einsum("...hv,wv->...hw", Yi, Wi)


def num_freq(delta: int) -> int:
    """Number of stored complex frequency points P in the rfft2 layout."""
    return delta * (delta // 2 + 1)


def num_freq_full(delta: int) -> int:
    """Frequency points in the full complex spectrum (``spectrum="complex"``)."""
    return delta * delta


def num_freq_real(delta: int) -> int:
    """Frequency points in the compact Hermitian layout (``spectrum="real"``).

    The rect rfft2 layout (delta x delta_h) still stores u-redundant rows in
    its self-conjugate columns (v = 0, and v = delta/2 for even delta):
    T[u, v] = conj(T[delta-u, v]) there.  Dropping them leaves
    delta^2/2 + 2 points for even delta and (delta^2 + 1)/2 for odd — just
    over half the full spectrum, vs 0.5625x for the rect layout at delta=16.
    """
    return len(_compact_layout_np(delta)[0])


@functools.lru_cache(maxsize=None)
def _compact_layout_np(delta: int):
    """Gather/scatter index maps between the rect rfft2 layout and the
    compact Hermitian frequency list.

    Returns ``(store, src, sgn)`` numpy arrays:

    - ``store`` (P_real,) int32: flat rect indices (u * delta_h + v) kept in
      the compact layout, in stored order.
    - ``src``   (delta * delta_h,) int32: for every rect point, the compact
      index holding its value (its own slot, or its u-conjugate mirror
      ``(delta - u) % delta`` for dropped points).
    - ``sgn``   (delta * delta_h,) float32: +1 for stored points, -1 for
      dropped ones (imag plane is negated when reading through the mirror).
    """
    d = delta
    dh = d // 2 + 1
    keep = np.ones((d, dh), dtype=bool)
    # Self-conjugate columns: only u in [0, d//2] carries information.
    keep[d // 2 + 1:, 0] = False
    if d % 2 == 0:
        keep[d // 2 + 1:, d // 2] = False
    store = np.flatnonzero(keep.ravel())
    comp_of_rect = -np.ones(d * dh, dtype=np.int64)
    comp_of_rect[store] = np.arange(store.size)
    src = np.empty(d * dh, dtype=np.int64)
    sgn = np.empty(d * dh, dtype=np.float32)
    for u in range(d):
        for v in range(dh):
            r = u * dh + v
            if comp_of_rect[r] >= 0:
                src[r], sgn[r] = comp_of_rect[r], 1.0
            else:
                m = ((d - u) % d) * dh + v
                src[r], sgn[r] = comp_of_rect[m], -1.0
    return (store.astype(np.int32), src.astype(np.int32), sgn)


def compact_layout(delta: int):
    """jnp copies of the (store, src, sgn) compact-layout index maps."""
    store, src, sgn = _compact_layout_np(delta)
    return jnp.asarray(store), jnp.asarray(src), jnp.asarray(sgn)


def pack_half_spectrum(Tr, Ti, delta: int):
    """Rect rfft2 planes (..., delta, delta_h) -> compact (..., P_real)."""
    store, _, _ = compact_layout(delta)
    dh = delta // 2 + 1
    Tr = jnp.take(Tr.reshape(*Tr.shape[:-2], delta * dh), store, axis=-1)
    Ti = jnp.take(Ti.reshape(*Ti.shape[:-2], delta * dh), store, axis=-1)
    return Tr, Ti


def unpack_half_spectrum(Zr, Zi, delta: int):
    """Compact planes (..., P >= P_real) -> rect rfft2 (..., delta, delta_h).

    Trailing padding past P_real (e.g. all-to-all divisibility padding) is
    ignored: every ``src`` index points below P_real.
    """
    _, src, sgn = compact_layout(delta)
    dh = delta // 2 + 1
    shape = (*Zr.shape[:-1], delta, dh)
    Zr = jnp.take(Zr, src, axis=-1).reshape(shape)
    Zi = (jnp.take(Zi, src, axis=-1) * sgn.astype(Zi.dtype)).reshape(shape)
    return Zr, Zi


def fft2_full_tiles(x, delta: int):
    """Batched full fft2 of real tiles: (..., delta, delta) -> two
    (..., delta, delta) planes (the ``spectrum="complex"`` twin)."""
    Fr, Fi, *_ = dft_mats(delta)
    Ar = jnp.einsum("uh,...hw->...uw", Fr, x)
    Ai = jnp.einsum("uh,...hw->...uw", Fi, x)
    Tr = jnp.einsum("...uw,vw->...uv", Ar, Fr) - jnp.einsum("...uw,vw->...uv", Ai, Fi)
    Ti = jnp.einsum("...uw,vw->...uv", Ar, Fi) + jnp.einsum("...uw,vw->...uv", Ai, Fr)
    return Tr, Ti


def ifft2_full_tiles(Zr, Zi, delta: int):
    """Batched full ifft2: two (..., delta, delta) planes -> real tiles.

    Returns Re(Finv @ Z @ Finv^T); the imaginary part cancels for spectra of
    real signals.
    """
    _, _, _, _, Fvr, Fvi, _, _ = dft_mats(delta)
    Yr = jnp.einsum("hu,...uv->...hv", Fvr, Zr) - jnp.einsum("hu,...uv->...hv", Fvi, Zi)
    Yi = jnp.einsum("hu,...uv->...hv", Fvr, Zi) + jnp.einsum("hu,...uv->...hv", Fvi, Zr)
    return jnp.einsum("...hv,wv->...hw", Yr, Fvr) - jnp.einsum("...hv,wv->...hw", Yi, Fvi)
