"""DFT-as-matmul: the TPU-native replacement for NEON FFT butterflies.

The paper computes 16x16 tile FFTs with hand-vectorised butterflies. A
systolic MXU hates butterfly networks but eats dense 16x16 matmuls, so we
express every (i)rfft2 of a tile as two small matrix products against
precomputed DFT matrices:

    rfft2(x)  = F_full @ x @ F_half^T            (x real, delta x delta)
    irfft2(Z) = Re( (Finv @ Z) @ Wr^T )          (Z complex, delta x delta_h)

where delta_h = delta//2 + 1 and Wr folds the Hermitian-redundant columns
back with weight 2 (columns 0 and Nyquist with weight 1).

All complex arithmetic is struct-of-arrays (separate real/imag planes);
neither the MXU nor Pallas has a native complex dtype.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def _dft_mats_np(delta: int):
    """Precompute (numpy, float64 -> float32) all DFT matrices for a tile size."""
    dh = delta // 2 + 1
    u = np.arange(delta)
    # Forward full DFT: F[u, h] = exp(-2i pi u h / delta)
    ang = -2.0 * np.pi * np.outer(u, u) / delta
    F = np.cos(ang) + 1j * np.sin(ang)
    F_half = F[:dh, :]                      # rfft over the last axis
    # Inverse full DFT (axis 0): Finv[h, u] = exp(+2i pi u h / delta) / delta
    Finv = np.conj(F).T / delta
    # Weighted inverse-rfft (last axis): x[., w] = Re(sum_v c_v Y[., v] e^{2i pi v w/delta})/delta
    v = np.arange(dh)
    c = np.where((v == 0) | (v == delta // 2), 1.0, 2.0)
    angw = 2.0 * np.pi * np.outer(np.arange(delta), v) / delta
    W = (np.cos(angw) + 1j * np.sin(angw)) * c[None, :] / delta   # (delta, dh)
    return (
        F.real.astype(np.float32), F.imag.astype(np.float32),
        F_half.real.astype(np.float32), F_half.imag.astype(np.float32),
        Finv.real.astype(np.float32), Finv.imag.astype(np.float32),
        W.real.astype(np.float32), W.imag.astype(np.float32),
    )


def dft_mats(delta: int):
    """jnp copies of all DFT matrices for tile size ``delta``."""
    return tuple(jnp.asarray(m) for m in _dft_mats_np(delta))


def rfft2_tiles(x, delta: int):
    """Batched rfft2 of real tiles via matmul.

    x: (..., delta, delta) real -> (Tr, Ti): (..., delta, delta_h).
    """
    Fr, Fi, Fhr, Fhi, *_ = dft_mats(delta)
    # A = F @ x  (x real): 2 real matmuls
    Ar = jnp.einsum("uh,...hw->...uw", Fr, x)
    Ai = jnp.einsum("uh,...hw->...uw", Fi, x)
    # T = A @ F_half^T: (Ar + iAi)(Fhr^T + iFhi^T)
    Tr = jnp.einsum("...uw,vw->...uv", Ar, Fhr) - jnp.einsum("...uw,vw->...uv", Ai, Fhi)
    Ti = jnp.einsum("...uw,vw->...uv", Ar, Fhi) + jnp.einsum("...uw,vw->...uv", Ai, Fhr)
    return Tr, Ti


def irfft2_tiles(Zr, Zi, delta: int):
    """Batched irfft2 via matmul. (Zr, Zi): (..., delta, delta_h) -> (..., delta, delta) real."""
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    # Y = Finv @ Z (complex x complex)
    Yr = jnp.einsum("hu,...uv->...hv", Fvr, Zr) - jnp.einsum("hu,...uv->...hv", Fvi, Zi)
    Yi = jnp.einsum("hu,...uv->...hv", Fvr, Zi) + jnp.einsum("hu,...uv->...hv", Fvi, Zr)
    # x = Re( Y @ W^T ) = Yr @ Wr^T - Yi @ Wi^T
    return jnp.einsum("...hv,wv->...hw", Yr, Wr) - jnp.einsum("...hv,wv->...hw", Yi, Wi)


def num_freq(delta: int) -> int:
    """Number of stored complex frequency points P in the rfft2 layout."""
    return delta * (delta // 2 + 1)
