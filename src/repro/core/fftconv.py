"""FFT-based convolution (the paper's algorithm): the stage primitives.

Four stages, kept as separate functions so the stage graph in
``repro.conv.stages`` can place collectives *between* stages (nFFT) or
inside stage 3 (the wFFT baseline), and so the kernel transform can run
once per weight version (``ConvPlan.prepare``):

  1. ``input_transform``   I (B,C,H,W)      -> D (P, M, C)   [rfft2 of 16x16 tiles]
  2. ``kernel_transform``  K (C',C,kh,kw)   -> G (P, C, C')  [conjugate rfft2]
  3. ``cgemm``             Z[p] = D[p] @ G[p]                [hot stage]
  4. ``output_inverse``    Z (P, M, C')     -> O (B,C',Ho,Wo) [irfft2 + crop]

All complex tensors are (real, imag) pairs of float arrays. ``M = B*X*Delta``
(tile count), ``P = delta*(delta//2+1)`` frequency points.

Convolution here is ML cross-correlation; ``conv2d_direct`` is the oracle.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.core import dft
from repro.core.dft import (
    rfft2_tiles, irfft2_tiles, fft2_full_tiles, ifft2_full_tiles,
    pack_half_spectrum, unpack_half_spectrum,
)


# --------------------------------------------------------------------------
# Spectrum layouts
# --------------------------------------------------------------------------
#
# Three frequency-axis layouts share the (P, M, C)-shaped stage interface:
#
#   "rect"    P = delta * (delta//2 + 1)  — the historical rfft2 grid; still
#             carries u-redundant rows in its self-conjugate columns
#             (0.5625x the full spectrum at delta=16).
#   "real"    P = num_freq_real(delta)    — compact Hermitian frequency list
#             (~0.51x at delta=16); the ConvPlan default.
#   "complex" P = delta^2                 — full spectrum; the measurement
#             twin the analyze invariants compare collective bytes against.
#
# Plans only use "real"/"complex"; "rect" remains the no-argument default of
# the raw stage primitives for direct callers.

SPECTRA = ("real", "complex")


def freq_count(spec: ConvSpec, spectrum: str = "rect") -> int:
    """Stored frequency points P for a spectrum layout."""
    if spectrum == "rect":
        return spec.P
    if spectrum == "real":
        return dft.num_freq_real(spec.delta)
    if spectrum == "complex":
        return dft.num_freq_full(spec.delta)
    raise ValueError(f"unknown spectrum {spectrum!r}")


# --------------------------------------------------------------------------
# Oracle
# --------------------------------------------------------------------------

def conv2d_direct(x, k, *, padding=0, compute_dtype=None):
    """Direct convolution oracle: lax.conv_general_dilated, NCHW/OIHW.

    ``padding`` is an int or ``(pad_h, pad_w)``, symmetric per axis —
    the same convention as the FFT path (lax wants (lo, hi) per dim).
    ``compute_dtype`` casts the operands (f32 accumulation, result back in
    ``x.dtype``) — the direct-backend analogue of the FFT schedules' hot
    CGEMM operand cast.
    """
    pad = (padding, padding) if isinstance(padding, int) else padding
    out_dtype = x.dtype
    acc = {}
    if compute_dtype is not None:
        x, k = x.astype(compute_dtype), k.astype(compute_dtype)
        acc = dict(preferred_element_type=jnp.float32)
    y = jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1),
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        dimension_numbers=("NCHW", "OIHW", "NCHW"), **acc,
    )
    return y.astype(out_dtype) if compute_dtype is not None else y


# --------------------------------------------------------------------------
# Stage 1: input transform
# --------------------------------------------------------------------------

def extract_tiles(x, spec: ConvSpec):
    """(B, C, H, W) -> overlap-save patches (B, C, X, Delta, delta, delta)."""
    d = spec.delta
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (spec.pad_h, spec.Hp - spec.H - spec.pad_h),
                    (spec.pad_w, spec.Wp - spec.W - spec.pad_w)))
    h_idx = jnp.arange(spec.X)[:, None] * spec.t_h + jnp.arange(d)[None, :]
    w_idx = jnp.arange(spec.D)[:, None] * spec.t_w + jnp.arange(d)[None, :]
    patches = x[:, :, h_idx[:, :, None, None], w_idx[None, None, :, :]]
    # (B, C, X, delta, Delta, delta) -> (B, C, X, Delta, delta, delta)
    return patches.transpose(0, 1, 2, 4, 3, 5)


def _tiles_to_spectrum(tiles, spec: ConvSpec, spectrum: str):
    """Real tile batch (..., delta, delta) -> flat spectrum planes (..., P)."""
    if spectrum == "complex":
        Tr, Ti = fft2_full_tiles(tiles, spec.delta)
        P = spec.delta * spec.delta
        return Tr.reshape(*Tr.shape[:-2], P), Ti.reshape(*Ti.shape[:-2], P)
    Tr, Ti = rfft2_tiles(tiles, spec.delta)
    if spectrum == "real":
        return pack_half_spectrum(Tr, Ti, spec.delta)
    if spectrum == "rect":
        P = spec.P
        return Tr.reshape(*Tr.shape[:-2], P), Ti.reshape(*Ti.shape[:-2], P)
    raise ValueError(f"unknown spectrum {spectrum!r}")


def input_transform(x, spec: ConvSpec, *, dtype=jnp.float32,
                    spectrum: str = "rect"):
    """Stage 1: I -> D (P, M, C) as (real, imag)."""
    patches = extract_tiles(x.astype(dtype), spec)     # (B, C, X, Dl, d, d)
    Tr, Ti = _tiles_to_spectrum(patches, spec, spectrum)
    P = Tr.shape[-1]                                   # == freq_count(...)
    def to_pmc(T):                                     # (B, C, X, Dl, P)
        T = T.transpose(4, 0, 2, 3, 1)                 # (P, B, X, Dl, C)
        return T.reshape(P, spec.M, spec.C)
    return to_pmc(Tr), to_pmc(Ti)


# --------------------------------------------------------------------------
# Stage 2: kernel transform
# --------------------------------------------------------------------------

def kernel_transform(k, spec: ConvSpec, *, dtype=jnp.float32,
                     spectrum: str = "rect"):
    """Stage 2: K -> G (P, C, C') as (real, imag); imag is conjugated."""
    d = spec.delta
    kp = jnp.pad(k.astype(dtype), ((0, 0), (0, 0),
                                   (0, d - spec.kh), (0, d - spec.kw)))
    Tr, Ti = _tiles_to_spectrum(kp, spec, spectrum)    # (C', C, P)
    P = Tr.shape[-1]                                   # == freq_count(...)
    def to_pcc(T):
        return T.transpose(2, 1, 0).reshape(P, spec.C, spec.Cout)
    return to_pcc(Tr), to_pcc(-Ti)                     # conj: F*(K)


# --------------------------------------------------------------------------
# Stage 4: inverse transform
# --------------------------------------------------------------------------

def z_to_tiles(Z, spec: ConvSpec):
    """(P, M, C') frequency layout -> per-tile (B, C', X, Dl, d, dh)."""
    d, dh = spec.delta, spec.delta_h
    Z = Z.reshape(d, dh, spec.B, spec.X, spec.D, spec.Cout)
    return Z.transpose(2, 5, 3, 4, 0, 1)               # (B, C', X, Dl, d, dh)


def z_to_flat_tiles(Z, spec: ConvSpec, P: int):
    """(P', M, C') flat frequency layout -> per-tile (B, C', X, Dl, P).

    ``P`` is the layout's true point count; rows past it (all-to-all
    divisibility padding added by the nfft schedule) are dropped.
    """
    Z = Z[:P].reshape(P, spec.B, spec.X, spec.D, spec.Cout)
    return Z.transpose(1, 4, 2, 3, 0)                  # (B, C', X, Dl, P)


def assemble_output_tiles(y, spec: ConvSpec):
    """Inverse-transformed tiles (B, C', X, Dl, d, d) -> O (B, C', Ho, Wo)
    (overlap-save crop + spatial reassembly)."""
    y = y[..., :spec.t_h, :spec.t_w]
    y = y.transpose(0, 1, 2, 4, 3, 5).reshape(
        spec.B, spec.Cout, spec.X * spec.t_h, spec.D * spec.t_w)
    return y[:, :, :spec.Ho, :spec.Wo]


def output_inverse(Zr, Zi, spec: ConvSpec, *, spectrum: str = "rect"):
    """Stage 4: Z (P, M, C') -> O (B, C', Ho, Wo).

    The P axis may carry trailing padding past the layout's point count
    (nfft all-to-all divisibility); it is sliced off here.
    """
    d = spec.delta
    if spectrum == "rect":
        y = irfft2_tiles(z_to_tiles(Zr[:spec.P], spec),
                         z_to_tiles(Zi[:spec.P], spec), d)
    elif spectrum == "real":
        P = dft.num_freq_real(d)
        Zr, Zi = unpack_half_spectrum(z_to_flat_tiles(Zr, spec, P),
                                      z_to_flat_tiles(Zi, spec, P), d)
        y = irfft2_tiles(Zr, Zi, d)
    elif spectrum == "complex":
        P = d * d
        shape = (spec.B, spec.Cout, spec.X, spec.D, d, d)
        y = ifft2_full_tiles(z_to_flat_tiles(Zr, spec, P).reshape(shape),
                             z_to_flat_tiles(Zi, spec, P).reshape(shape), d)
    else:
        raise ValueError(f"unknown spectrum {spectrum!r}")
    return assemble_output_tiles(y, spec)


# --------------------------------------------------------------------------
# Full algorithm
# --------------------------------------------------------------------------

def make_spec(x_shape, k_shape, padding=0, delta=16) -> ConvSpec:
    B, C, H, W = x_shape
    Cout, C2, kh, kw = k_shape
    if C != C2:
        raise ValueError(f"channel mismatch: input C={C}, kernel C={C2}")
    pad = (padding, padding) if isinstance(padding, int) else padding
    return ConvSpec(B=B, C=C, Cout=Cout, H=H, W=W, kh=kh, kw=kw,
                    pad_h=pad[0], pad_w=pad[1], delta=delta)


def fft_conv2d(x, k, *, padding=0, delta=16, three_m: bool = True):
    """Deprecated: use ``repro.conv.plan_conv(..., backend="fft-xla")``.

    FFT-based 2-D convolution (cross-correlation), differentiable.
    Thin shim over the plan API with the old signature.
    """
    warnings.warn(
        "fft_conv2d is deprecated; use repro.conv.plan_conv(x.shape, "
        "k.shape, backend='fft-xla') and call the plan",
        DeprecationWarning, stacklevel=2)
    from repro.conv import plan_conv
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     delta=delta, backend="fft-xla", three_m=three_m)
    return plan(x, k)


def fft_conv2d_pallas(x, k, *, padding=0, delta=16, three_m: bool = True,
                      bm=None, bn=None, bk=None):
    """Deprecated: use ``repro.conv.plan_conv(..., backend="fft-pallas")``.

    fft_conv2d with the hot CGEMM running through the Pallas TPU kernel
    (kernels/cgemm; interpret mode on CPU). Inference path — no custom VJP.
    """
    warnings.warn(
        "fft_conv2d_pallas is deprecated; use repro.conv.plan_conv(x.shape,"
        " k.shape, backend='fft-pallas', bm=..., bn=..., bk=...) and call "
        "the plan", DeprecationWarning, stacklevel=2)
    from repro.conv import plan_conv
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     delta=delta, backend="fft-pallas", three_m=three_m,
                     bm=bm, bn=bn, bk=bk)
    return plan(x, k)
