"""Core FFT-based convolution algorithm (the paper's contribution)."""
from repro.core.conv_spec import ConvSpec
from repro.core.fftconv import (
    fft_conv2d, fft_conv2d_pallas, conv2d_direct, make_spec,
    input_transform, kernel_transform, output_inverse,
)
from repro.core.cgemm import cgemm, cgemm_3m, cgemm_4m
from repro.core.dft import rfft2_tiles, irfft2_tiles, dft_mats, num_freq

__all__ = [
    "ConvSpec", "fft_conv2d", "fft_conv2d_pallas", "conv2d_direct",
    "make_spec",
    "input_transform", "kernel_transform", "output_inverse",
    "cgemm", "cgemm_3m", "cgemm_4m",
    "rfft2_tiles", "irfft2_tiles", "dft_mats", "num_freq",
]
