"""Batched complex matrix multiplication (stage 3, the hot stage).

Z[p] = D[p] @ G[p] for every frequency point p, with complex operands kept
as separate real/imag planes (struct-of-arrays).

Two arithmetic schedules:
  * 4M: Zr = DrGr - DiGi ; Zi = DrGi + DiGr          (4 real matmuls)
  * 3M (Karatsuba): T1 = DrGr ; T2 = DiGi ; T3 = (Dr+Di)(Gr+Gi)
       Zr = T1 - T2 ; Zi = T3 - T1 - T2              (3 real matmuls, -25% MXU FLOPs)

Shapes: D (P, M, C), G (P, C, N) -> Z (P, M, N).
"""
from __future__ import annotations

import jax.numpy as jnp


def _mm(a, b, precision, acc):
    return jnp.einsum("pmc,pcn->pmn", a, b, precision=precision,
                      preferred_element_type=acc)


def cgemm_4m(Dr, Di, Gr, Gi, *, precision=None, acc=jnp.float32):
    Zr = _mm(Dr, Gr, precision, acc) - _mm(Di, Gi, precision, acc)
    Zi = _mm(Dr, Gi, precision, acc) + _mm(Di, Gr, precision, acc)
    return Zr, Zi


def cgemm_3m(Dr, Di, Gr, Gi, *, precision=None, acc=jnp.float32):
    T1 = _mm(Dr, Gr, precision, acc)
    T2 = _mm(Di, Gi, precision, acc)
    T3 = _mm(Dr + Di, Gr + Gi, precision, acc)
    return T1 - T2, T3 - T1 - T2


def cgemm(Dr, Di, Gr, Gi, *, three_m: bool = True, precision=None,
          acc=jnp.float32):
    f = cgemm_3m if three_m else cgemm_4m
    return f(Dr, Di, Gr, Gi, precision=precision, acc=acc)
