"""Convolution + overlap-save tiling specification."""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static geometry of one FFT-based convolution.

    Overlap-save with tile ``delta x delta``: every tile of the padded input
    yields a ``t x t`` block of valid outputs, ``t = delta - k + 1``.
    """
    B: int
    C: int
    Cout: int
    H: int
    W: int
    kh: int
    kw: int
    pad_h: int = 0
    pad_w: int = 0
    delta: int = 16

    def __post_init__(self):
        if self.kh > self.delta or self.kw > self.delta:
            raise ValueError(
                f"kernel {self.kh}x{self.kw} exceeds tile size {self.delta}")

    # ---- derived geometry -------------------------------------------------
    @property
    def t_h(self) -> int:              # valid outputs per tile, rows
        return self.delta - self.kh + 1

    @property
    def t_w(self) -> int:
        return self.delta - self.kw + 1

    @property
    def Ho(self) -> int:
        return self.H + 2 * self.pad_h - self.kh + 1

    @property
    def Wo(self) -> int:
        return self.W + 2 * self.pad_w - self.kw + 1

    @property
    def X(self) -> int:                # tile grid rows
        return math.ceil(self.Ho / self.t_h)

    @property
    def D(self) -> int:                # tile grid cols (paper's Delta)
        return math.ceil(self.Wo / self.t_w)

    @property
    def n_tiles(self) -> int:
        return self.X * self.D

    @property
    def M(self) -> int:                # CGEMM row count: B * X * Delta
        return self.B * self.n_tiles

    @property
    def delta_h(self) -> int:          # rfft column count
        return self.delta // 2 + 1

    @property
    def P(self) -> int:                # stored complex frequency points
        return self.delta * self.delta_h

    # padded input extent covered by the tile grid (>= H + 2*pad)
    @property
    def Hp(self) -> int:
        return (self.X - 1) * self.t_h + self.delta

    @property
    def Wp(self) -> int:
        return (self.D - 1) * self.t_w + self.delta

    def freq_points(self, spectrum: str = "rect") -> int:
        """Stored frequency points for a spectrum layout (see
        ``repro.core.fftconv``): the rect rfft2 grid (``P``), the compact
        Hermitian list (``"real"``), or the full spectrum (``"complex"``)."""
        if spectrum == "rect":
            return self.P
        if spectrum == "complex":
            return self.delta * self.delta
        if spectrum == "real":
            d = self.delta
            return d * d // 2 + 2 if d % 2 == 0 else (d * d + 1) // 2
        raise ValueError(f"unknown spectrum {spectrum!r}")

    # ---- cost model (for roofline / napkin math) --------------------------
    def direct_flops(self) -> int:
        return 2 * self.B * self.Cout * self.C * self.Ho * self.Wo * self.kh * self.kw

    def cgemm_flops(self, three_m: bool = False,
                    spectrum: str = "rect") -> int:
        per_point = (6 if three_m else 8) * self.M * self.C * self.Cout
        return self.freq_points(spectrum) * per_point

    def transform_flops(self) -> int:
        # input + kernel + inverse transforms, 6 small matmuls each ~2*d^3-ish
        d, dh = self.delta, self.delta_h
        per_tile = 2 * d * d * d * 2 + 4 * 2 * d * d * dh   # fwd: F@x (2) + A@Fh (4)
        inv_per_tile = 4 * 2 * d * d * dh + 2 * 2 * d * dh * d
        return (self.B * self.n_tiles * self.C + self.C * self.Cout) * per_tile \
            + self.B * self.n_tiles * self.Cout * inv_per_tile
