"""Decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

Layers are weight-stacked and scanned over *pattern units*: the repeating
block of the architecture's layer pattern (gemma3: 5 local + 1 global;
gemma2: local+global; mixtral/mamba2/hymba: a single layer). Kinds and
local/global choices inside a unit are therefore *static*, so the banded
sliding-window fast path stays available, while the HLO size is
O(pattern-unit), independent of depth. DeepSeek's leading dense layer(s)
sit outside the scanned MoE stack.

Hymba's three forced-global layers (first/middle/last of a uniform 'H'
pattern) cannot be static under the unit scan; they use a traced effective
window (HUGE for global) instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models import layers as L
from repro.parallel.act_sharding import constrain, current_mesh

HUGE_WINDOW = 1 << 30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def _make_block_params(key, cfg: ModelConfig, kind: str, moe: bool):
    ks = L.split_keys(key, 8)
    p = {"ln1": L.make_norm_params(ks[0], cfg.d_model, cfg.norm)}
    if kind in ("G", "L", "H"):
        p["attn"] = (L.make_mla_params(ks[1], cfg) if cfg.mla
                     else L.make_attn_params(ks[1], cfg))
    if kind in ("M", "H"):
        p["mamba"] = L.make_mamba_params(ks[2], cfg)
        if kind == "H":
            p["attn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["mamba_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if kind != "M" and cfg.d_ff:
        p["ln2"] = L.make_norm_params(ks[3], cfg.d_model, cfg.norm)
        p["ffn"] = (L.make_moe_params(ks[4], cfg) if moe
                    else L.make_mlp_params(ks[4], cfg.d_model, cfg.d_ff,
                                           cfg.mlp))
    if cfg.post_norm:
        p["pn1"] = L.make_norm_params(ks[5], cfg.d_model, cfg.norm)
        if "ffn" in p:
            p["pn2"] = L.make_norm_params(ks[6], cfg.d_model, cfg.norm)
    return p


def _scan_geometry(cfg: ModelConfig):
    """(unit_kinds, n_units) for the scanned part of the stack."""
    unit = cfg.layer_pattern
    n_scan = cfg.n_layers - cfg.first_dense
    assert n_scan % len(unit) == 0, (cfg.name, n_scan, unit)
    return unit, n_scan // len(unit)


def init_lm_params(cfg: ModelConfig, key):
    ks = L.split_keys(key, 6)
    unit, n_units = _scan_geometry(cfg)
    moe = cfg.n_experts > 0
    kinds = cfg.layer_kinds()

    def unit_params(k):
        uks = L.split_keys(k, len(unit))
        return [_make_block_params(uks[j], cfg, unit[j], moe)
                for j in range(len(unit))]

    unit_keys = jax.random.split(ks[0], n_units)
    stack = jax.vmap(unit_params)(unit_keys)     # list of (n_units, ...) trees
    params = {
        "embed": L.dense_init(ks[1], (cfg.vocab, cfg.d_model)),
        "final_norm": L.make_norm_params(ks[2], cfg.d_model, cfg.norm),
        "layers": stack,
    }
    for i in range(cfg.first_dense):
        params[f"dense_{i}"] = _make_block_params(
            jax.random.fold_in(ks[3], i), cfg, kinds[i], False)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[4], (cfg.d_model, cfg.vocab))
    if cfg.n_meta_tokens:
        params["meta_tokens"] = L.dense_init(
            ks[5], (cfg.n_meta_tokens, cfg.d_model))
    return params


# --------------------------------------------------------------------------
# per-layer scan data (traced where the pattern can't make them static)
# --------------------------------------------------------------------------

def _unit_flags(cfg: ModelConfig):
    """Static per-unit-position locality when uniform across units, else
    traced per-layer effective windows (hymba's forced-global layers)."""
    unit, n_units = _scan_geometry(cfg)
    locs = cfg.local_flags()[cfg.first_dense:]
    theta_local = cfg.rope_theta_local or cfg.rope_theta
    thetas = jnp.asarray([theta_local if lc else cfg.rope_theta
                          for lc in locs], jnp.float32)
    thetas = thetas.reshape(n_units, len(unit))
    uniform = all(locs[u * len(unit) + j] == locs[j]
                  for u in range(n_units) for j in range(len(unit)))
    if uniform:
        static_local = [locs[j] for j in range(len(unit))]
        wins = jnp.zeros((n_units, len(unit)), jnp.int32)   # unused
    else:
        static_local = [None] * len(unit)     # decide per layer at runtime
        wins = jnp.asarray([cfg.window if lc else HUGE_WINDOW
                            for lc in locs],
                           jnp.int32).reshape(n_units, len(unit))
    return static_local, thetas, wins


# --------------------------------------------------------------------------
# block forward
# --------------------------------------------------------------------------

def _block_forward(p, x, cfg: ModelConfig, kind: str, *, positions,
                   window, theta, cache=None, cache_index=None,
                   use_flash=False, ring=False):
    """window: 0 (global), static int (banded local), or traced scalar.
    ring: the attention cache is a window-sized ring buffer."""
    h = L.apply_norm(x, p["ln1"], cfg.norm)
    new_cache = {}
    if kind in ("G", "L"):
        if cfg.mla:
            att, nc = L.mla_forward(p["attn"], h, cfg, positions=positions,
                                    theta=theta, cache=cache,
                                    cache_index=cache_index,
                                    use_flash=use_flash)
        else:
            att, nc = L.attn_forward(p["attn"], h, cfg, positions=positions,
                                     window=window, theta=theta,
                                     cache=cache, cache_index=cache_index,
                                     use_flash=use_flash, ring=ring)
        if nc is not None:
            new_cache.update(nc)
        if cfg.post_norm:
            att = L.apply_norm(att, p["pn1"], cfg.norm)
        x = x + att
    elif kind == "M":
        mo, ns = L.mamba_forward(p["mamba"], h, cfg, state=cache)
        if ns is not None:
            new_cache.update(ns)
        x = x + mo
    elif kind == "H":
        attn_cache = ssm_cache = None
        if cache is not None:
            attn_cache = {"k": cache["k"], "v": cache["v"]}
            ssm_cache = {k: cache[k] for k in
                         ("ssm", "conv_x", "conv_B", "conv_C")}
        att, nc = L.attn_forward(p["attn"], h, cfg, positions=positions,
                                 window=window, theta=theta,
                                 cache=attn_cache, cache_index=cache_index,
                                 use_flash=use_flash)
        mo, ns = L.mamba_forward(p["mamba"], h, cfg, state=ssm_cache)
        comb = 0.5 * (L.rms_norm(att, p["attn_norm"])
                      + L.rms_norm(mo, p["mamba_norm"]))
        if nc is not None:
            new_cache.update(nc)
        if ns is not None:
            new_cache.update(ns)
        x = x + comb
    if kind != "M" and cfg.d_ff:
        h2 = L.apply_norm(x, p["ln2"], cfg.norm)
        if "w_gate_router" in p.get("ffn", {}):
            mesh = current_mesh() if cfg.moe_ep else None
            if mesh is not None and \
                    cfg.n_experts % mesh.shape["model"] == 0:
                from repro.parallel.ep_moe import moe_forward_ep
                f = moe_forward_ep(p["ffn"], h2, cfg, mesh)
            else:
                f = L.moe_forward(p["ffn"], h2, cfg)
        else:
            f = L.mlp_forward(p["ffn"], h2, cfg.mlp)
        if cfg.post_norm:
            f = L.apply_norm(f, p["pn2"], cfg.norm)
        x = x + f
    return x, (new_cache or None)


# --------------------------------------------------------------------------
# unit scan (shared by train / prefill / decode)
# --------------------------------------------------------------------------

def _tree_index(tree, j):
    return jax.tree.map(lambda a: a[j], tree)


def _run_stack(params, cfg: ModelConfig, x, positions, *, cache=None,
               cache_index=None, use_flash=False, remat=False):
    """Run dense prefix + scanned units. cache is the per-unit-position dict
    from init_cache ({"u{j}": (n_units, ...) stacks}); position stacks ride
    along as scan xs, so per-position shapes (ring vs full) are fine.
    Returns (x, new_cache_dict)."""
    unit, n_units = _scan_geometry(cfg)
    static_local, thetas, wins = _unit_flags(cfg)
    layers_u = params["layers"]          # list-of-unit trees, stacked

    cache_tup = None if cache is None else tuple(
        cache[f"u{j}"] for j in range(len(unit)))

    def unit_body(x, xs):
        if cache_tup is None:
            p_unit, theta_u, win_u = xs
            c_tup = None
        else:
            p_unit, c_tup, theta_u, win_u = xs
        ncs = []
        for j, kind in enumerate(unit):
            if static_local[j] is None:
                window = win_u[j]                       # traced (hymba)
            else:
                window = cfg.window if static_local[j] else 0
            ring = (cfg.ring_local_cache and static_local[j] is True
                    and cfg.window > 0)
            c_j = None if c_tup is None else c_tup[j]
            x, nc = _block_forward(
                p_unit[j], x, cfg, kind, positions=positions, window=window,
                theta=theta_u[j], cache=c_j, cache_index=cache_index,
                use_flash=use_flash, ring=ring)
            x = constrain(x, "seq")
            ncs.append(nc)
        if cache_tup is None:
            return x, None
        return x, tuple(ncs)

    body = unit_body
    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    xs = ((layers_u, thetas, wins) if cache_tup is None
          else (layers_u, cache_tup, thetas, wins))
    x, new_cache_tup = jax.lax.scan(body, x, xs)
    if cache_tup is None:
        return x, None
    return x, {f"u{j}": new_cache_tup[j] for j in range(len(unit))}


# --------------------------------------------------------------------------
# embeddings / logits
# --------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, img_embeds=None,
           prepend_meta=False):
    cdt = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cdt)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(cdt), x], axis=1)
    if prepend_meta and cfg.n_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta_tokens"].astype(cdt)[None],
            (x.shape[0], cfg.n_meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
    return constrain(x, "seq")


def _logits(params, cfg: ModelConfig, x):
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(x.dtype)
    logits = constrain((x @ w).astype(jnp.float32), "logits")
    if cfg.softcap_final:
        logits = jnp.tanh(logits / cfg.softcap_final) * cfg.softcap_final
    return logits


def _dense_prefix(params, cfg, x, positions, cache, cache_index, use_flash):
    new_cache = {}
    kinds = cfg.layer_kinds()
    for i in range(cfg.first_dense):
        c = None if cache is None else cache[f"dense_{i}"]
        x, nc = _block_forward(params[f"dense_{i}"], x, cfg, kinds[i],
                               positions=positions, window=0,
                               theta=cfg.rope_theta, cache=c,
                               cache_index=cache_index, use_flash=use_flash)
        new_cache[f"dense_{i}"] = nc
    return x, new_cache


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def lm_forward(params, cfg: ModelConfig, tokens, *, img_embeds=None,
               use_flash=False, remat=True):
    """Training/scoring forward: (B, S) tokens -> (B, S_total, vocab)."""
    x = _embed(params, cfg, tokens, img_embeds, prepend_meta=True)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = _dense_prefix(params, cfg, x, positions, None, None, use_flash)
    x, _ = _run_stack(params, cfg, x, positions, use_flash=use_flash,
                      remat=remat)
    return _logits(params, cfg, x)


# ---- KV cache --------------------------------------------------------------

def _kind_cache(cfg: ModelConfig, kind: str, lead, batch: int, max_len: int):
    cdt = jnp.dtype(cfg.dtype)
    c = {}
    if kind in ("G", "L", "H"):
        if cfg.mla:
            c["c_kv"] = jnp.zeros(lead + (batch, max_len, cfg.kv_lora), cdt)
            c["k_rope"] = jnp.zeros(lead + (batch, max_len, cfg.rope_dim),
                                    cdt)
        else:
            kv = lead + (batch, cfg.padded_kv, max_len, cfg.head_dim)
            c["k"] = jnp.zeros(kv, cdt)
            c["v"] = jnp.zeros(kv, cdt)
    if kind in ("M", "H"):
        W = cfg.conv_width
        c["ssm"] = jnp.zeros(lead + (batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32)
        c["conv_x"] = jnp.zeros(lead + (batch, W - 1, cfg.d_inner), cdt)
        c["conv_B"] = jnp.zeros(lead + (batch, W - 1, cfg.ssm_state), cdt)
        c["conv_C"] = jnp.zeros(lead + (batch, W - 1, cfg.ssm_state), cdt)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache: one stacked (n_units, ...) entry per pattern-unit
    position (so per-position lengths can differ: with ``ring_local_cache``
    sliding-window layers allocate only a window-sized ring) plus one entry
    per leading dense layer."""
    unit, n_units = _scan_geometry(cfg)
    static_local, _, _ = _unit_flags(cfg)
    kinds = cfg.layer_kinds()
    cache = {}
    for j, kind in enumerate(unit):
        ring = (cfg.ring_local_cache and static_local[j] is True
                and cfg.window > 0)
        len_j = min(max_len, cfg.window) if ring else max_len
        cache[f"u{j}"] = _kind_cache(cfg, kind, (n_units,), batch, len_j)
    for i in range(cfg.first_dense):
        cache[f"dense_{i}"] = _kind_cache(cfg, kinds[i], (), batch, max_len)
    return cache


def lm_prefill(params, cfg: ModelConfig, tokens, cache, *, img_embeds=None,
               use_flash=True):
    """Prefill: run the full sequence, fill cache at offset 0.
    Returns (last-token logits, new_cache, seq_len_written)."""
    x = _embed(params, cfg, tokens, img_embeds, prepend_meta=True)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, new_cache = _dense_prefix(params, cfg, x, positions, cache,
                                 jnp.int32(0), use_flash)
    x, sc = _run_stack(params, cfg, x, positions, cache=cache,
                       cache_index=jnp.int32(0), use_flash=use_flash)
    new_cache.update(sc)
    return _logits(params, cfg, x[:, -1:]), new_cache, S


def lm_decode_step(params, cfg: ModelConfig, tokens, pos, cache):
    """One decode step. tokens: (B, 1); pos: scalar int32 write index.
    Returns (logits, new_cache)."""
    x = _embed(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(pos + jnp.arange(S)[None], (B, S))
    x, new_cache = _dense_prefix(params, cfg, x, positions, cache, pos,
                                 False)
    x, sc = _run_stack(params, cfg, x, positions, cache=cache,
                       cache_index=pos)
    new_cache.update(sc)
    return _logits(params, cfg, x), new_cache
