"""Neural-net layers shared by the ten assigned architectures.

Pure functions over param pytrees (dicts of jnp arrays). Conventions:
  * params are float32; compute dtype per ModelConfig (bf16 default).
  * RoPE is the interleaved-pair form (shard-friendly along head_dim:
    pairs are adjacent, so a head_dim shard of >=2 never splits a pair).
  * attention is either `attend_full` (materialised scores; decode and
    short-seq train) or `attend_flash` (online-softmax block scan; long
    prefill, with a banded fast path for sliding-window layers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.parallel.act_sharding import constrain

NEG_INF = -2.3819763e38   # most-negative bf16-representable


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, scale=0.02):
    return (scale * jax.random.truncated_normal(key, -2, 2, shape,
                                                jnp.float32))


def split_keys(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# convolution (through the plan/execute engine)
# --------------------------------------------------------------------------

def conv2d_planned(x, k, *, padding=1, backend="auto", schedule="auto",
                   mesh=None, compute_dtype=None, weights_version=None):
    """NCHW convolution through ``repro.conv`` for model layers.

    Training (``weights_version=None``): executes ``plan(x, k)`` — fully
    differentiable in ``x`` and ``k`` via the plan-level VJP, on every
    backend x schedule.

    Serving (``weights_version`` given, e.g. the train step the weights
    were loaded from): executes a *prepared* plan — the kernel transform is
    cached under (plan, version) and skipped on every call; passing a new
    version after a weight update invalidates and re-prepares.
    """
    from repro.conv import plan_conv
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     backend=backend, schedule=schedule, mesh=mesh,
                     compute_dtype=compute_dtype)
    if weights_version is None:
        return plan(x, k)
    return plan.prepare(k, weights_version=weights_version)(x)


def maxpool2x2(x):
    """2x2/stride-2 max pool over the spatial axes of NCHW ``x``."""
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def conv_block(x, k, bias=None, *, activation="none", residual=None,
               padding=1, backend="auto", schedule="auto", mesh=None,
               compute_dtype=None, weights_version=None):
    """Conv + bias + activation (+ residual) as ONE fused plan.

    The elementwise tail is an ``Epilogue`` frozen into the plan and
    executed inside the pipeline's stage 4 — on the local output slab,
    before the f32 -> x.dtype cast, with zero extra collectives under the
    sharded schedules — instead of separate XLA ops on the gathered
    output.  Differentiable in ``x``, ``k`` AND ``bias``/``residual`` via
    the plan-level VJP; ``weights_version`` routes through a prepared plan
    exactly like ``conv2d_planned``.
    """
    from repro.conv import Epilogue, plan_conv
    ep = Epilogue(bias=bias is not None, activation=activation,
                  residual=residual is not None)
    plan = plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     backend=backend, schedule=schedule, mesh=mesh,
                     compute_dtype=compute_dtype, epilogue=ep)
    if weights_version is None:
        return plan(x, k, bias=bias, residual=residual)
    return plan.prepare(k, weights_version=weights_version)(
        x, bias=bias, residual=residual)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def make_norm_params(key, d, kind):
    if kind == "rms":
        return {"gamma": jnp.zeros((d,), jnp.float32)}
    return {"gamma": jnp.ones((d,), jnp.float32),
            "beta": jnp.zeros((d,), jnp.float32)}


def apply_norm(x, p, kind):
    if kind == "rms":
        return rms_norm(x, p["gamma"])
    return layer_norm(x, p["gamma"], p["beta"])


# --------------------------------------------------------------------------
# RoPE (interleaved pairs)
# --------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr = x.astype(jnp.float32).reshape(x.shape[:-1] + (hd // 2, 2))
    x0, x1 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x0 * cos - x1 * sin, x0 * sin + x1 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def _softcap(s, cap):
    return jnp.tanh(s / cap) * cap if cap else s


def _static_zero_window(window) -> bool:
    return isinstance(window, int) and window == 0


def attend_full(q, k, v, *, q_positions, kv_positions, window=0,
                softcap=0.0, causal=True, kv_len=None):
    """Materialised-score attention, head-expanded layout.

    q, k, v: (B, H, S, hd) — GQA kv heads are pre-expanded to H by the
    caller (a free local slice under head-TP sharding).
    window: 0 / static int / traced scalar (HUGE_WINDOW disables in effect).
    kv_len: optional (B,) valid cache length for decode.
    """
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    s = _softcap(s, softcap)
    qp = q_positions[:, None, :, None]
    kp = kv_positions[:, None, None, :]
    mask = jnp.ones(s.shape, dtype=bool)
    if causal:
        mask &= kp <= qp
    if not _static_zero_window(window):
        mask &= kp > qp - window
    if kv_len is not None:
        mask &= kp < kv_len[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attend_flash(q, k, v, *, q_positions, kv_positions, window=0,
                 softcap=0.0, causal=True, q_block=512, kv_block=512):
    """Online-softmax blocked attention (pure-JAX flash).

    q, k, v: (B, H, S, hd), kv pre-expanded to H. Static sliding-window
    layers get a banded schedule: only the kv blocks intersecting the window
    are visited (O(S*W) instead of O(S^2)). A traced window applies the mask
    but visits all blocks. The inner step is jax.checkpoint'ed so the
    backward pass recomputes score blocks instead of storing O(S^2)
    residuals (the flash recompute schedule)."""
    B, H, Sq, hd = q.shape
    Skv, vd = k.shape[2], v.shape[-1]

    def pick_block(S, pref):
        """Largest block <= pref dividing S (hymba: S = 4096 + 128 meta)."""
        b = min(pref, S)
        while S % b:
            b -= 1
        return b

    q_block, kv_block = pick_block(Sq, q_block), pick_block(Skv, kv_block)
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = q.reshape(B, H, nq, q_block, hd).astype(jnp.float32)
    kb = k.reshape(B, H, nk, kv_block, hd).astype(jnp.float32)
    vb = v.reshape(B, H, nk, kv_block, vd).astype(jnp.float32)
    qp = q_positions.reshape(B, nq, q_block)
    kp = kv_positions.reshape(B, nk, kv_block)

    banded = isinstance(window, int) and window > 0
    masked = not _static_zero_window(window)
    if banded:
        # kv block j for q block i runs over offsets i - wb .. i,
        # wb = ceil((window + q_block) / kv_block)
        wb = -(-(window + q_block) // kv_block)
        n_steps = min(nk, wb + 1)
    else:
        n_steps = nk

    def per_qblock(qi, q_i, qp_i):
        # q_i: (B, H, q_block, hd); qp_i: (B, q_block)
        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, vd), jnp.float32)

        @jax.checkpoint
        def step(carry, js):
            m, l, acc = carry
            if banded:
                j_raw = qi - (n_steps - 1) + js
                visit = j_raw >= 0            # clamped re-visits are masked
                j = jnp.maximum(j_raw, 0)
            else:
                j, visit = js, None
            k_j = jax.lax.dynamic_index_in_dim(kb, j, 2, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, 2, keepdims=False)
            kp_j = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j) * scale
            s = _softcap(s, softcap)
            msk = jnp.ones(s.shape, dtype=bool)
            if causal:
                msk &= kp_j[:, None, None, :] <= qp_i[:, None, :, None]
            if masked:
                msk &= kp_j[:, None, None, :] > \
                    qp_i[:, None, :, None] - window
            if visit is not None:
                msk &= visit
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_j)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(n_steps))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.vmap(per_qblock, in_axes=(0, 2, 1), out_axes=2)(
        jnp.arange(nq), qb, qp)
    # out: (B, H, nq, q_block, vd) -> (B, H, Sq, vd)
    return out.reshape(B, H, Sq, vd).astype(v.dtype)


# --------------------------------------------------------------------------
# GQA attention layer (with qk-norm, softcap, local/global, cache)
# --------------------------------------------------------------------------

def head_mask(cfg: ModelConfig):
    """(padded_heads,) 1.0 for real head slots, 0.0 for padding slots.
    Real heads of real kv-group g occupy slots [g*G_pad, g*G_pad+G_real);
    padded kv groups (g >= n_kv) are entirely dead."""
    Hp, Hkvp = cfg.padded_heads, cfg.padded_kv
    g_pad, g_real = Hp // Hkvp, cfg.n_heads // cfg.n_kv
    m = [1.0 if (h // g_pad) < cfg.n_kv and (h % g_pad) < g_real else 0.0
         for h in range(Hp)]
    return jnp.asarray(m, jnp.float32)


def make_attn_params(key, cfg: ModelConfig):
    d, H, Hkv, hd = cfg.d_model, cfg.padded_heads, cfg.padded_kv, cfg.head_dim
    ks = split_keys(key, 4)
    p = {"wq": dense_init(ks[0], (d, H, hd)),
         "wk": dense_init(ks[1], (d, Hkv, hd)),
         "wv": dense_init(ks[2], (d, Hkv, hd)),
         "wo": dense_init(ks[3], (H, hd, d))}
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def attn_forward(p, x, cfg: ModelConfig, *, positions, window,
                 theta, cache=None, cache_index=None, use_flash=False,
                 ring=False):
    """Self-attention. x: (B, S, d).

    window: 0 (global) / static int (banded local) / traced scalar.
    cache: None (train/prefill-no-cache) or dict(k, v, (B,Hkv,Smax,hd)).
    cache_index: scalar write offset for decode; None -> prefill writes 0..S.
    ring: cache is a window-sized ring buffer (slot = position % W); only
    valid with a static local window.
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    H, Hkv, hd = cfg.padded_heads, cfg.padded_kv, cfg.head_dim
    G = H // Hkv
    cdt = x.dtype
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt)),
                  "heads")
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt)),
                  "heads")
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt)),
                  "heads")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    q = q.transpose(0, 2, 1, 3)                      # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)                      # (B, Hkv, S, hd)
    v = v.transpose(0, 2, 1, 3)

    def expand(t):                                   # kv -> H heads
        return jnp.repeat(t, G, axis=1) if G > 1 else t

    softcap = cfg.softcap_attn
    new_cache = None
    if cache is not None and ring:
        W = cache["k"].shape[2]
        idx = jnp.int32(0) if cache_index is None else cache_index
        if S > 1:
            if S >= W:
                # prefill: keep the last W tokens, rolled so slot == pos % W
                kW, vW = k[:, :, -W:], v[:, :, -W:]
                shift = (idx + S) % W
                ck = jnp.roll(kW, shift, axis=2)
                cv = jnp.roll(vW, shift, axis=2)
            else:        # short prefill: contiguous write (no wrap at idx=0)
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, idx % W, axis=2)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, idx % W, axis=2)
            new_cache = {"k": ck, "v": cv}
            fn = attend_flash if use_flash else attend_full
            out = fn(q, expand(k), expand(v), q_positions=positions,
                     kv_positions=positions, window=window, softcap=softcap)
        else:
            slot = idx % W
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=2)
            new_cache = {"k": ck, "v": cv}
            slots = jnp.arange(W)
            delta = jnp.mod(idx - slots, W)          # age of each slot
            kv_pos = jnp.where(delta <= idx, idx - delta, idx + 1)
            kv_positions = jnp.broadcast_to(kv_pos[None], (B, W))
            out = attend_full(q, expand(ck), expand(cv),
                              q_positions=positions,
                              kv_positions=kv_positions, window=window,
                              softcap=softcap)
        out = out.transpose(0, 2, 1, 3)              # (B, S, H, hd)
        if cfg.padded_heads != cfg.n_heads or cfg.padded_kv != cfg.n_kv:
            out = out * head_mask(cfg).astype(cdt)[None, None, :, None]
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
        return out, new_cache
    if cache is not None:
        idx = 0 if cache_index is None else cache_index
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=2)
        new_cache = {"k": ck, "v": cv}
        if S > 1:
            # prefill: the cache was written starting at idx (== 0 for a
            # fresh cache), so attention over it equals attention over the
            # freshly-projected local k/v — use the flash path on those
            # rather than score-materialising against the padded cache.
            fn = attend_flash if use_flash else attend_full
            out = fn(q, expand(k), expand(v), q_positions=positions,
                     kv_positions=positions, window=window, softcap=softcap)
        else:
            kv_positions = jnp.broadcast_to(
                jnp.arange(ck.shape[2])[None], (B, ck.shape[2]))
            kv_len = (idx + S) * jnp.ones((B,), jnp.int32)
            out = attend_full(q, expand(ck), expand(cv),
                              q_positions=positions,
                              kv_positions=kv_positions, window=window,
                              softcap=softcap, kv_len=kv_len)
    elif use_flash:
        out = attend_flash(q, expand(k), expand(v), q_positions=positions,
                           kv_positions=positions, window=window,
                           softcap=softcap)
    else:
        out = attend_full(q, expand(k), expand(v), q_positions=positions,
                          kv_positions=positions, window=window,
                          softcap=softcap)
    out = out.transpose(0, 2, 1, 3)                  # (B, S, H, hd)
    if cfg.padded_heads != cfg.n_heads or cfg.padded_kv != cfg.n_kv:
        # zero the padding slots: exact n_heads semantics (and zero grads
        # into the dead wq/wk/wv/wo rows)
        out = out * head_mask(cfg).astype(cdt)[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------

def make_mla_params(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, dl = cfg.head_dim, cfg.rope_dim, cfg.v_head_dim, cfg.kv_lora
    ks = split_keys(key, 6)
    return {
        "w_dkv": dense_init(ks[0], (d, dl)),          # down-proj to latent
        "w_kr": dense_init(ks[1], (d, dr)),           # shared rope key
        "w_uk": dense_init(ks[2], (dl, H, dn)),       # latent -> key(nope)
        "w_uv": dense_init(ks[3], (dl, H, dv)),       # latent -> value
        "w_q": dense_init(ks[4], (d, H, dn + dr)),    # query (lite: no q-lora)
        "wo": dense_init(ks[5], (H, dv, d)),
    }


def mla_forward(p, x, cfg: ModelConfig, *, positions, theta,
                cache=None, cache_index=None, use_flash=False):
    """MLA. Cache holds the compressed latent (c_kv, k_rope) only.

    * decode (S==1): the *absorbed* form — q projected into latent space, so
      per-step compute/cache scale with kv_lora, not H*head_dim.
    * train / prefill: the *folded* form — k = [k_nope | k_rope broadcast]
      so the score is one dot product and the standard (flash) attention
      kernels apply. Prefill still writes only the compressed cache.
    """
    B, S, d = x.shape
    H, dn, dr, dv, dl = (cfg.n_heads, cfg.head_dim, cfg.rope_dim,
                         cfg.v_head_dim, cfg.kv_lora)
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"].astype(cdt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, theta)
    c_kv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"].astype(cdt))
    k_rope = rope(jnp.einsum("bsd,dr->bsr", x,
                             p["w_kr"].astype(cdt))[:, :, None, :],
                  positions, theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        idx = 0 if cache_index is None else cache_index
        c_all = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx,
                                                    axis=1)
        r_all = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope,
                                                    idx, axis=1)
        new_cache = {"c_kv": c_all, "k_rope": r_all}

    if cache is not None and S == 1:
        Skv = c_all.shape[1]
        kv_len = (0 if cache_index is None else cache_index) + S
        # absorbed: q_nope -> latent space
        q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"].astype(cdt))
        s = (jnp.einsum("bshl,btl->bhst", q_lat.astype(jnp.float32),
                        c_all.astype(jnp.float32))
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          r_all.astype(jnp.float32)))
        s = s / jnp.sqrt(dn + dr).astype(jnp.float32)
        kp = jnp.arange(Skv)[None, None, None, :]
        qp = positions[:, None, :, None]
        mask = (kp <= qp) & (kp < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btl->bshl", pr,
                           c_all.astype(jnp.float32)).astype(cdt)
        out = jnp.einsum("bshl,lhv->bshv", o_lat, p["w_uv"].astype(cdt))
    else:
        # folded: concat nope+rope into one head_dim, standard attention.
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"].astype(cdt))
        vv = jnp.einsum("bsl,lhv->bshv", c_kv, p["w_uv"].astype(cdt))
        k_fold = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, dr))], axis=-1)
        q_fold = jnp.concatenate([q_nope, q_rope], axis=-1)
        # MLA scales by sqrt(dn+dr); attend_* scale by sqrt(head_dim)=same.
        qf = q_fold.transpose(0, 2, 1, 3)                # (B, H, S, hd')
        kf = k_fold.transpose(0, 2, 1, 3)
        vf = vv.transpose(0, 2, 1, 3)
        fn = attend_flash if use_flash else attend_full
        out = fn(qf, kf, vf, q_positions=positions, kv_positions=positions,
                 window=0)
        out = out.transpose(0, 2, 1, 3)                  # (B, S, H, dv)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cdt)), new_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def make_mlp_params(key, d, dff, kind):
    ks = split_keys(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, dff)),
                "w_up": dense_init(ks[1], (d, dff)),
                "w_down": dense_init(ks[2], (dff, d))}
    return {"w_up": dense_init(ks[0], (d, dff)),
            "w_down": dense_init(ks[1], (dff, d))}


def mlp_forward(p, x, kind):
    cdt = x.dtype
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True)
        h = act(x @ p["w_gate"].astype(cdt)) * (x @ p["w_up"].astype(cdt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(cdt), approximate=True)
    return h @ p["w_down"].astype(cdt)


# --------------------------------------------------------------------------
# MoE (sorted capacity dispatch + per-expert block einsum; TP over d_ff)
# --------------------------------------------------------------------------

def make_moe_params(key, cfg: ModelConfig):
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.expert_dff
    ks = split_keys(key, 5)
    p = {"w_gate_router": dense_init(ks[0], (d, E)),
         "w1": dense_init(ks[1], (E, d, dff)),        # gate proj
         "w2": dense_init(ks[2], (E, d, dff)),        # up proj
         "w3": dense_init(ks[3], (E, dff, d))}        # down proj
    if cfg.n_shared:
        p["shared"] = make_mlp_params(ks[4], d, cfg.n_shared * dff, cfg.mlp)
    return p


def _moe_group(xt, p, cfg: ModelConfig, cap: int):
    """Dispatch + expert compute for one group of tokens. xt: (Tg, d)."""
    Tg, d = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    cdt = xt.dtype
    logits = (xt @ p["w_gate_router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)              # (Tg, K)
    if cfg.renorm_topk:
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    flat_e = topi.reshape(-1)                         # (Tg*K,)
    flat_t = jnp.repeat(jnp.arange(Tg), K)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e)                       # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(Tg * K) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, E * cap)   # overflow -> scratch

    buf = jnp.zeros((E * cap + 1, d), cdt).at[slot].set(
        xt[st] * keep[:, None].astype(cdt))
    eb = buf[:E * cap].reshape(E, cap, d)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else functools.partial(
            jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("ecd,edf->ecf", eb, p["w1"].astype(cdt))) * \
            jnp.einsum("ecd,edf->ecf", eb, p["w2"].astype(cdt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", eb,
                                   p["w1"].astype(cdt)), approximate=True)
    eo = jnp.einsum("ecf,efd->ecd", h, p["w3"].astype(cdt))
    gathered = eo.reshape(E * cap, d)[jnp.minimum(slot, E * cap - 1)]
    contrib = gathered * (sw * keep).astype(cdt)[:, None]
    return jnp.zeros((Tg, d), cdt).at[st].add(contrib)


def moe_forward(p, x, cfg: ModelConfig):
    """Token-choice top-k MoE with capacity; differentiable sort dispatch.

    Tokens are split into ``cfg.moe_groups`` dispatch groups (the launcher
    sets this to the DP size), vmapped so sort/scatter stay shard-local
    under GSPMD. The (E, C, d) expert batch keeps d_ff TP-sharded (the
    nFFT-style "keep the hot GEMM local" schedule; EP a2a is a strategy
    variant, see DESIGN.md)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = cfg.moe_groups if T % cfg.moe_groups == 0 else 1
    Tg = T // G
    cap = int(min(Tg, max(8, round(Tg * K / E * cfg.capacity_factor))))
    xg = x.reshape(G, Tg, d)
    out = jax.vmap(lambda xt: _moe_group(xt, p, cfg, cap))(xg)
    out = out.reshape(B, S, d)
    if cfg.n_shared:
        out = out + mlp_forward(p["shared"], x, cfg.mlp)
    return out


# --------------------------------------------------------------------------
# Mamba2 (SSD, chunked) + single-step decode
# --------------------------------------------------------------------------

def make_mamba_params(key, cfg: ModelConfig):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = split_keys(key, 9)
    return {
        "w_z": dense_init(ks[0], (d, di)),
        "w_x": dense_init(ks[1], (d, di)),
        "w_B": dense_init(ks[2], (d, N)),
        "w_C": dense_init(ks[3], (d, N)),
        "w_dt": dense_init(ks[4], (d, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "conv_x": dense_init(ks[5], (cfg.conv_width, di), 0.2),
        "conv_B": dense_init(ks[6], (cfg.conv_width, N), 0.2),
        "conv_C": dense_init(ks[7], (cfg.conv_width, N), 0.2),
        "out_norm": jnp.zeros((di,), jnp.float32),
        "w_out": dense_init(ks[8], (di, d)),
    }


def _causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (W, C).
    state: (B, W-1, C) carry for decode. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return y, new_state


def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk):
    """Mamba2 SSD, chunked linear-time scan.

    xh: (B, S, H, P) head inputs; dt: (B, S, H) softplus'd step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B, S, N) (single group).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    f32 = jnp.float32
    xc = xh.reshape(Bsz, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    dA = dtc * A[None, None, None, :]                 # (B, nc, Q, H) <= 0
    dAcs = jnp.cumsum(dA, axis=2)                     # inclusive cumsum
    # intra-chunk: L[i,j] = exp(dAcs_i - dAcs_j) for i >= j
    Ldec = dAcs[:, :, :, None, :] - dAcs[:, :, None, :, :]   # (B,nc,Q,Q,H)
    Ldec = jnp.where(jnp.tril(jnp.ones((chunk, chunk), bool))[None, None,
                                                              :, :, None],
                     jnp.exp(Ldec), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)    # (B,nc,Q,Q)
    w = scores[..., None] * Ldec * dtc[:, :, None, :, :]     # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # chunk summary state: S_c = sum_j exp(dAcs_Q - dAcs_j) dt_j B_j x_j
    decay_to_end = jnp.exp(dAcs[:, :, -1:, :] - dAcs)         # (B,nc,Q,H)
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                    decay_to_end * dtc, Bc, xc)               # (B,nc,H,P,N)
    # inter-chunk recurrence over c
    chunk_decay = jnp.exp(dAcs[:, :, -1, :])                  # (B,nc,H)

    def scan_fn(h, inp):
        Sc_c, dec_c = inp
        h_new = h * dec_c[..., None, None] + Sc_c
        return h_new, h                                       # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, Pd, N), f32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (Sc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc, h_prev, jnp.exp(dAcs))
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y.astype(xh.dtype), hT


def mamba_forward(p, x, cfg: ModelConfig, *, state=None):
    """Mamba2 mixer. x: (B, S, d).
    state: None (train) or dict(ssm (B,H,P,N) f32, conv_x/conv_B/conv_C).
    Decode path (S small) updates state stepwise."""
    B, S, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cdt = x.dtype
    z = x @ p["w_z"].astype(cdt)
    xi = x @ p["w_x"].astype(cdt)
    Bm = x @ p["w_B"].astype(cdt)
    Cm = x @ p["w_C"].astype(cdt)
    dt_raw = (x @ p["w_dt"].astype(cdt)).astype(jnp.float32) + p["dt_bias"]
    dt = jax.nn.softplus(dt_raw)                      # (B, S, H)
    A = -jnp.exp(p["A_log"])                          # (H,)

    def pick_chunk(S, pref):
        b = min(pref, S)
        while S % b:
            b -= 1
        return b

    cs = {} if state is None else state
    xi, cx = _causal_conv1d(xi, p["conv_x"], cs.get("conv_x"))
    Bm, cB = _causal_conv1d(Bm, p["conv_B"], cs.get("conv_B"))
    Cm, cC = _causal_conv1d(Cm, p["conv_C"], cs.get("conv_C"))
    xi, Bm, Cm = jax.nn.silu(xi), jax.nn.silu(Bm), jax.nn.silu(Cm)
    xh = xi.reshape(B, S, H, Pd)

    if state is None:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm,
                           chunk=pick_chunk(S, cfg.ssm_chunk))
        new_state = None
    elif S >= 8:
        # prefill: chunked SSD from zero state, carry the final state out.
        y, hT = ssd_chunked(xh, dt, A, Bm, Cm,
                            chunk=pick_chunk(S, cfg.ssm_chunk))
        new_state = {"ssm": hT, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    else:
        # stepwise recurrence (decode): h' = h * exp(dt A) + dt B (x) ;
        # y = C . h' + D x  — scan over the S new tokens (usually S == 1).
        def step(h, inp):
            x_t, dt_t, B_t, C_t = inp        # (B,H,P),(B,H),(B,N),(B,N)
            dec = jnp.exp(dt_t * A[None, :])              # (B,H)
            upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t,
                             x_t.astype(jnp.float32))
            h = h * dec[..., None, None] + upd
            y_t = jnp.einsum("bn,bhpn->bhp", C_t, h)
            return h, y_t
        h0 = cs["ssm"]
        hT, ys = jax.lax.scan(
            step, h0,
            (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
             dt.transpose(1, 0, 2),
             Bm.transpose(1, 0, 2).astype(jnp.float32),
             Cm.transpose(1, 0, 2).astype(jnp.float32)))
        y = ys.transpose(1, 0, 2, 3).astype(cdt)          # (B,S,H,P)
        new_state = {"ssm": hT, "conv_x": cx, "conv_B": cB, "conv_C": cC}

    y = y + xh * p["D"].astype(cdt)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    return y @ p["w_out"].astype(cdt), new_state


def init_mamba_state(cfg: ModelConfig, batch, dtype=jnp.float32):
    W = cfg.conv_width
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
        "conv_x": jnp.zeros((batch, W - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
    }
