"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub —
``input_specs`` feeds precomputed frame embeddings, per the assignment).

Encoder: bidirectional attention over frames + sinusoidal positions.
Decoder: causal self-attention + cross-attention, learned positions.
Both stacks are weight-stacked and scanned.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models import layers as L
from repro.parallel.act_sharding import constrain


def sinusoid_posemb(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1),
                       jnp.float32)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def _enc_layer(key, cfg):
    ks = L.split_keys(key, 4)
    return {"ln1": L.make_norm_params(ks[0], cfg.d_model, cfg.norm),
            "attn": L.make_attn_params(ks[1], cfg),
            "ln2": L.make_norm_params(ks[2], cfg.d_model, cfg.norm),
            "mlp": L.make_mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp)}


def _dec_layer(key, cfg):
    ks = L.split_keys(key, 6)
    return {"ln1": L.make_norm_params(ks[0], cfg.d_model, cfg.norm),
            "attn": L.make_attn_params(ks[1], cfg),
            "lnx": L.make_norm_params(ks[2], cfg.d_model, cfg.norm),
            "xattn": L.make_attn_params(ks[3], cfg),
            "ln2": L.make_norm_params(ks[4], cfg.d_model, cfg.norm),
            "mlp": L.make_mlp_params(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp)}


def init_whisper_params(cfg: ModelConfig, key):
    ks = L.split_keys(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _enc_layer(k, cfg))(enc_keys),
        "enc_norm": L.make_norm_params(ks[2], cfg.d_model, cfg.norm),
        "dec_layers": jax.vmap(lambda k: _dec_layer(k, cfg))(dec_keys),
        "dec_norm": L.make_norm_params(ks[3], cfg.d_model, cfg.norm),
        "embed": L.dense_init(ks[4], (cfg.vocab, cfg.d_model)),
        "dec_posemb": L.dense_init(ks[4], (cfg.max_dec_len, cfg.d_model)),
    }


# --------------------------------------------------------------------------
# attention helpers (no RoPE; absolute position embeddings)
# --------------------------------------------------------------------------

def _proj_qkv(p, xq, xkv, cfg):
    cdt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(cdt))
    # whisper is MHA (n_kv == n_heads): no expansion needed
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def _attend(p, q, k, v, cfg, *, causal, q_pos, kv_pos, use_flash=False):
    fn = L.attend_flash if use_flash else L.attend_full
    out = fn(q, k, v, q_positions=q_pos, kv_positions=kv_pos, causal=causal)
    out = out.transpose(0, 2, 1, 3)                  # (B, S, H, hd)
    if cfg.padded_heads != cfg.n_heads or cfg.padded_kv != cfg.n_kv:
        out = out * L.head_mask(cfg).astype(out.dtype)[None, None, :, None]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))


# --------------------------------------------------------------------------
# encoder / decoder forward
# --------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T, d) precomputed frame embeddings (conv-frontend stub)."""
    cdt = jnp.dtype(cfg.dtype)
    B, T, _ = frames.shape
    use_flash = T >= 2048          # bidirectional flash for long frame seqs
    x = frames.astype(cdt) + sinusoid_posemb(T, cfg.d_model).astype(cdt)[None]
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, p):
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        q, k, v = _proj_qkv(p["attn"], h, h, cfg)
        x = x + _attend(p["attn"], q, k, v, cfg, causal=False,
                        q_pos=pos, kv_pos=pos, use_flash=use_flash)
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        return constrain(x + L.mlp_forward(p["mlp"], h, cfg.mlp), "seq"), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(x, params["enc_norm"], cfg.norm)


def decode_train(params, cfg: ModelConfig, enc_out, tokens):
    """Teacher-forced decoder: (B, S_dec) -> (B, S_dec, vocab)."""
    cdt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    T = enc_out.shape[1]
    x = params["embed"][tokens].astype(cdt) \
        + params["dec_posemb"][:S].astype(cdt)[None]
    dpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    epos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, p):
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        q, k, v = _proj_qkv(p["attn"], h, h, cfg)
        x = x + _attend(p["attn"], q, k, v, cfg, causal=True,
                        q_pos=dpos, kv_pos=dpos)
        h = L.apply_norm(x, p["lnx"], cfg.norm)
        q, k, v = _proj_qkv(p["xattn"], h, enc_out, cfg)
        x = x + _attend(p["xattn"], q, k, v, cfg, causal=False,
                        q_pos=dpos, kv_pos=epos)
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        return constrain(x + L.mlp_forward(p["mlp"], h, cfg.mlp), "seq"), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(x, params["dec_norm"], cfg.norm)
    return (x @ params["embed"].T.astype(cdt)).astype(jnp.float32)


# ---- serving ---------------------------------------------------------------

def init_dec_cache(cfg: ModelConfig, batch: int, enc_len: int):
    cdt = jnp.dtype(cfg.dtype)
    Ld = cfg.n_layers
    kv = (Ld, batch, cfg.padded_kv, cfg.max_dec_len, cfg.head_dim)
    xkv = (Ld, batch, cfg.padded_kv, enc_len, cfg.head_dim)
    return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt),
            "xk": jnp.zeros(xkv, cdt), "xv": jnp.zeros(xkv, cdt)}


def prefill_cross(params, cfg: ModelConfig, enc_out, cache):
    """Precompute per-layer cross k/v from the encoder output."""
    cdt = enc_out.dtype

    def body(_, xs):
        p, = xs
        k = jnp.einsum("btd,dhk->bthk", enc_out,
                       p["xattn"]["wk"].astype(cdt)).transpose(0, 2, 1, 3)
        v = jnp.einsum("btd,dhk->bthk", enc_out,
                       p["xattn"]["wv"].astype(cdt)).transpose(0, 2, 1, 3)
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(body, None, (params["dec_layers"],))
    return dict(cache, xk=xk, xv=xv)


def decode_step(params, cfg: ModelConfig, tokens, pos, cache):
    """One decoder step with self-cache write at ``pos`` and cached cross k/v.
    tokens: (B, 1). Returns (logits, new_cache)."""
    cdt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cdt) \
        + jax.lax.dynamic_slice_in_dim(params["dec_posemb"], pos, 1,
                                       axis=0).astype(cdt)[None, 0:1]
    dpos = jnp.broadcast_to(pos + jnp.arange(1)[None], (B, 1))
    T = cache["xk"].shape[3]
    epos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, xs):
        p, ck, cv, xk, xv = xs
        h = L.apply_norm(x, p["ln1"], cfg.norm)
        q, k, v = _proj_qkv(p["attn"], h, h, cfg)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=2)
        kv_pos = jnp.broadcast_to(jnp.arange(ck.shape[2])[None],
                                  (B, ck.shape[2]))
        out = L.attend_full(q, ck, cv, q_positions=dpos, kv_positions=kv_pos,
                            kv_len=(pos + 1) * jnp.ones((B,), jnp.int32))
        out = out.transpose(0, 2, 1, 3)              # (B, 1, H, hd)
        if cfg.padded_heads != cfg.n_heads or cfg.padded_kv != cfg.n_kv:
            out = out * L.head_mask(cfg).astype(cdt)[None, None, :, None]
        x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cdt))
        h = L.apply_norm(x, p["lnx"], cfg.norm)
        q, _, _ = _proj_qkv(p["xattn"], h, h, cfg)
        x = x + _attend(p["xattn"], q, xk, xv, cfg, causal=False,
                        q_pos=dpos, kv_pos=epos)
        h = L.apply_norm(x, p["ln2"], cfg.norm)
        return x + L.mlp_forward(p["mlp"], h, cfg.mlp), (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["dec_norm"], cfg.norm)
    logits = (x @ params["embed"].T.astype(cdt)).astype(jnp.float32)
    return logits, dict(cache, k=nk, v=nv)
