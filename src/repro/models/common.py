"""Model configuration shared by all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- attention variants -------------------------------------------------
    pad_heads: int = 0              # physical head count for TP (Megatron-
                                    # style padding: dead heads are masked so
                                    # semantics stay exactly n_heads; lets
                                    # e.g. 40 heads shard a 16-wide axis)
    pad_kv: int = 0                 # physical kv-head count (same idea)
    rope_theta: float = 1e4
    rope_theta_local: float = 0.0   # gemma3: different theta for local layers
    qk_norm: bool = False           # qwen3 / gemma3 per-head RMSNorm on q,k
    softcap_attn: float = 0.0       # gemma2 attention-logit softcap
    softcap_final: float = 0.0      # gemma2 final-logit softcap
    window: int = 0                 # sliding-window size for 'L' layers
    # layer kinds, cycled over n_layers: G global attn, L local attn,
    # M mamba2 mixer, H hymba parallel attn+ssm. Overridden by full_attn_idx.
    layer_pattern: Tuple[str, ...] = ("G",)
    full_attn_idx: Tuple[int, ...] = ()   # layers whose attention is global
                                          # even when the pattern is local
                                          # (hymba: first/middle/last)
    mlp: str = "swiglu"             # swiglu | geglu | gelu
    norm: str = "rms"               # rms | ln
    post_norm: bool = False         # gemma2/3 extra post-layer norms

    # --- MLA (deepseek) ------------------------------------------------------
    mla: bool = False
    kv_lora: int = 0
    rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    expert_dff: int = 0
    renorm_topk: bool = True
    first_dense: int = 0            # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25
    moe_groups: int = 1             # dispatch groups; launcher sets this to
                                    # the DP size so sort/scatter stay
                                    # per-shard under GSPMD
    moe_ep: bool = False            # expert-parallel boundary-a2a MoE
                                    # (parallel/ep_moe; needs an active
                                    # activation_sharding mesh context)

    # --- SSM (mamba2 / hymba) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 256

    # --- frontends / structure ----------------------------------------------
    frontend: str = "none"          # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0      # vlm: image tokens prepended
    encdec: bool = False            # whisper
    n_enc_layers: int = 0
    max_dec_len: int = 448          # whisper decoder length
    n_meta_tokens: int = 0          # hymba learnable prefix tokens
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma: scale embeddings by sqrt(d)
    ring_local_cache: bool = False  # sliding-window layers keep a window-
                                    # sized ring KV cache instead of the
                                    # full context (EXPERIMENTS §Perf)

    dtype: str = "bfloat16"

    # --- derived -------------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        return self.pad_heads or self.n_heads

    @property
    def padded_kv(self) -> int:
        return self.pad_kv or self.n_kv

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_pattern[i % len(self.layer_pattern)]
                     for i in range(self.n_layers))

    def local_flags(self) -> Tuple[bool, ...]:
        """Per-layer: does this layer's attention use the sliding window?"""
        kinds = self.layer_kinds()
        return tuple(
            self.window > 0 and kinds[i] in ("L", "H")
            and i not in self.full_attn_idx
            for i in range(self.n_layers))

    def encdec_split(self):
        """(encoder_params, decoder_params) for enc-dec models."""
        d = self.d_model
        attn_p = 4 * d * self.n_heads * self.head_dim
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        enc = self.n_enc_layers * (attn_p + mult * d * self.d_ff)
        dec = self.n_layers * (2 * attn_p + mult * d * self.d_ff)
        return enc, dec

    def n_params(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.encdec:
            enc, dec = self.encdec_split()
            return emb + self.max_dec_len * d + enc + dec
        per = 0
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            if kind in ("G", "L", "H"):
                if self.mla:
                    per_attn = (d * (self.kv_lora + self.rope_dim)
                                + self.kv_lora * self.n_heads
                                * (self.head_dim + self.v_head_dim)
                                + d * self.n_heads * (self.head_dim + self.rope_dim)
                                + self.n_heads * self.v_head_dim * d)
                else:
                    per_attn = (d * self.n_heads * self.head_dim
                                + 2 * d * self.n_kv * self.head_dim
                                + self.n_heads * self.head_dim * d)
                per += per_attn
            if kind in ("M", "H"):
                di, ns = self.d_inner, self.ssm_state
                per += d * 2 * di + 2 * d * ns + d * self.ssm_heads \
                    + di * d + self.conv_width * (di + 2 * ns)
            # FFN / MoE
            if kind == "M":
                pass                      # mamba2 blocks have no FFN
            elif self.n_experts and i >= self.first_dense:
                mult = 3 if self.mlp in ("swiglu", "geglu") else 2
                per += (self.n_experts + self.n_shared) * mult * d * self.expert_dff
                per += d * self.n_experts
            elif self.d_ff:
                mult = 3 if self.mlp in ("swiglu", "geglu") else 2
                per += mult * d * self.d_ff
        return emb + per

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        n_moe_layers = self.n_layers - self.first_dense
        inactive = n_moe_layers * (self.n_experts - self.top_k) * mult * d * self.expert_dff
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)
