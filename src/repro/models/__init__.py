from repro.models.common import ModelConfig, ShapeCell, SHAPES
from repro.models import layers, lm, whisper

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "layers", "lm", "whisper"]
