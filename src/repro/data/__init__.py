from repro.data.pipeline import (DataConfig, lm_batch, image_batch,
                                 frames_batch)

__all__ = ["DataConfig", "lm_batch", "image_batch", "frames_batch"]
