"""Deterministic, stateless-seekable synthetic data pipeline.

Every batch is a pure function of (seed, step), so restart-after-failure and
straggler fail-over replay the *exact* same stream with no pipeline state to
checkpoint — the fault-tolerance contract in DESIGN.md §5. Shardable: the
batch is produced host-locally then device_put with the step's sharding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"        # lm | images | frames


def _rng(cfg: DataConfig, step: int):
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD47A]))


def lm_batch(cfg: DataConfig, step: int):
    """Zipf-ish synthetic token stream with a learnable structure: token
    t+1 depends on t (bigram-ish), so small models show a falling loss."""
    r = _rng(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    base = r.zipf(1.3, size=(B, S)).clip(1, V - 1)
    # inject copy structure: 25% of positions repeat the previous token
    prev = np.roll(base, 1, axis=1)
    m = r.random((B, S)) < 0.25
    toks = np.where(m, prev, base).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def image_batch(cfg: DataConfig, step: int, *, chw=(3, 32, 32), n_class=10):
    r = _rng(cfg, step)
    B = cfg.global_batch
    y = r.integers(0, n_class, size=(B,))
    x = r.standard_normal((B,) + chw).astype(np.float32)
    # class-dependent mean so the task is learnable
    x += y[:, None, None, None].astype(np.float32) * 0.3
    return {"images": jnp.asarray(x), "labels": jnp.asarray(y, jnp.int32)}


def frames_batch(cfg: DataConfig, step: int, *, d_model: int, frames: int):
    """Whisper stub frontend: precomputed frame embeddings + text tokens."""
    r = _rng(cfg, step)
    B = cfg.global_batch
    f = r.standard_normal((B, frames, d_model)).astype(np.float32)
    toks = r.integers(1, cfg.vocab, size=(B, cfg.seq_len)).astype(np.int32)
    return {"frames": jnp.asarray(f),
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}
