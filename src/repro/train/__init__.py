from repro.train.step import (make_train_step, make_prefill_step,
                              make_decode_step, init_train_state,
                              cross_entropy)

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state", "cross_entropy"]
