"""Train / prefill / decode step builders used by the launcher and dry-run.

All steps are pure jax functions of (params, opt_state, batch) so they can be
``jax.jit``-ed with in/out shardings (GSPMD) for any mesh, or lowered against
``ShapeDtypeStruct``s for the dry-run.

Distributed-optimization features:
  * microbatching (gradient accumulation via lax.scan),
  * activation remat (per pattern-unit, policy ``nothing_saveable``),
  * gradient compression: grads cast to bf16 before the (GSPMD-inserted)
    data-parallel all-reduce, halving DP collective bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.optim import AdamWConfig, adamw_init, adamw_update


def cross_entropy(logits, labels, *, z_loss=1e-4, mask=None):
    """Masked softmax CE + z-loss. logits f32 (B, S, V); labels (B, S).

    Written so every op over V keeps a vocab-sharded logits tensor sharded
    under GSPMD: the label log-prob comes from a one-hot einsum (shardable
    reduction) instead of take_along_axis (a gather along the sharded dim,
    which forces a full logits all-gather — 40 GB/device at 152k vocab)."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.sum(shifted * onehot, axis=-1) + m[..., 0]
    ce = lse - ll
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(ce)
    mask = mask.astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _lm_loss(params, cfg: ModelConfig, batch, use_flash):
    tokens, labels = batch["tokens"], batch["labels"]
    img = batch.get("img_embeds")
    logits = LM.lm_forward(params, cfg, tokens, img_embeds=img,
                           use_flash=use_flash, remat=True)
    # frontend/meta prefix positions carry no labels
    prefix = logits.shape[1] - labels.shape[1]
    logits = logits[:, prefix:]
    return cross_entropy(logits, labels)


def _whisper_loss(params, cfg: ModelConfig, batch, use_flash):
    enc = WH.encode(params, cfg, batch["frames"])
    logits = WH.decode_train(params, cfg, enc, batch["tokens"])
    return cross_entropy(logits, batch["labels"])


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, use_flash: bool = False,
                    grad_bf16: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    loss_fn = _whisper_loss if cfg.encdec else _lm_loss

    def compute_grads(params, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch,
                                                      use_flash)
            return loss, grads
        # gradient accumulation: split the batch leading dim into chunks
        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc_loss, acc_g = carry
            loss, g = jax.value_and_grad(loss_fn)(params, cfg, mbatch,
                                                  use_flash)
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        zero_g = jax.tree.map(jnp.zeros_like, params)
        (tot, g), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
        return tot / microbatches, jax.tree.map(
            lambda x: x / microbatches, g)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if grad_bf16:
            # compression: DP all-reduce happens on the bf16 values
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             opt_cfg)
        return params, opt_state, dict(om, loss=loss)

    return train_step


def make_prefill_step(cfg: ModelConfig, *, use_flash: bool = True):
    if cfg.encdec:
        def prefill(params, batch, cache):
            enc = WH.encode(params, cfg, batch["frames"])
            cache = WH.prefill_cross(params, cfg, enc, cache)
            logits, cache = WH.decode_step(params, cfg, batch["tokens"],
                                           jnp.int32(0), cache)
            return logits, cache
        return prefill

    def prefill(params, batch, cache):
        img = batch.get("img_embeds")
        logits, cache, _ = LM.lm_prefill(params, cfg, batch["tokens"], cache,
                                         img_embeds=img, use_flash=use_flash)
        return logits, cache
    return prefill


def make_decode_step(cfg: ModelConfig):
    if cfg.encdec:
        def decode(params, tokens, pos, cache):
            return WH.decode_step(params, cfg, tokens, pos, cache)
        return decode

    def decode(params, tokens, pos, cache):
        return LM.lm_decode_step(params, cfg, tokens, pos, cache)
    return decode


def init_train_state(cfg: ModelConfig, key):
    init = WH.init_whisper_params if cfg.encdec else LM.init_lm_params
    params = init(cfg, key)
    return params, adamw_init(params)
