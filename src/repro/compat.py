"""Version compatibility shims for the jax API surface.

The repo targets current jax (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``); seed environments may carry an
older release where ``shard_map`` lives in ``jax.experimental`` (with
``check_rep``) and ``make_mesh`` has no ``axis_types``.  Everything that
builds meshes or shard_maps goes through these two wrappers.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with Auto axis types where supported."""
    kwargs = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = \
            (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def jaxpr_types():
    """The (Jaxpr, ClosedJaxpr) classes, wherever this jax version keeps
    them (``jax.extend.core`` on current jax, ``jax.core`` on older
    releases).  Used by the static analyzer to recurse into sub-jaxprs."""
    try:
        from jax.extend import core as xcore
        return xcore.Jaxpr, xcore.ClosedJaxpr
    except (ImportError, AttributeError):
        from jax import core as jcore
        return jcore.Jaxpr, jcore.ClosedJaxpr
