"""On-device measured autotuner for the conv engine (``backend="tuned"``).

The cost model behind ``backend="auto"`` ranks candidates by FLOPs, but the
direct/FFT crossover — and the best (schedule, block) configuration — is
machine-dependent (Zlateski et al.).  This module *measures* instead:

    from repro.conv import autotune
    winner = autotune.tune(x_shape, k_shape, padding=1)
    # -> TunedConfig(backend='fft-xla', schedule='local', ..., us_per_call=…)

or, threaded through the planner:

    plan = plan_conv(x_shape, k_shape, padding=1, backend="tuned")

``tune`` times every candidate (backend, schedule, frequency-layout
``spectrum``, sub-slab ``overlap``, cgemm ``bm/bn/bk``, ``dft_tile``
``dft_bt``) configuration on the actual device — warmup then
median-of-k, under a wall-clock budget — and persists the winner in a JSON
tuning cache so the tuning cost is paid once per machine.  Cache entries are
keyed by the spec signature + device kind + jax version: a new device or a
jax upgrade invalidates naturally (old keys simply never match).

Candidates are timed through the real planner with a representative
bias+relu epilogue, so the ``fft-pallas``/``local`` fused ``dft_tile``
inverse tail is part of the measurement (its ``dft_bt`` tile is a real
tuning axis, not a guess).

Environment knobs:

  ``REPRO_AUTOTUNE``            "0"/"false"/"off" disables measurement;
                                ``tune`` then falls back to the cost model
                                (cold cache + offline -> same answer as
                                ``backend="auto"``).  Cache *hits* are still
                                served.
  ``REPRO_AUTOTUNE_CACHE``      cache file path
                                (default ``~/.cache/repro_autotune.json``).
  ``REPRO_AUTOTUNE_BUDGET_MS``  wall-clock tuning budget per spec (default
                                2000).  The cost-model pick is always
                                measured; further candidates run until the
                                budget is spent.
  ``REPRO_AUTOTUNE_REPS``       timed repetitions per candidate (default 3,
                                median taken; 1 warmup/compile call first).

CI runs ``python -m repro.conv.autotune --selfcheck`` with the budget
clamped low: it tunes one small spec, drops the in-memory store, re-reads
the cache file and asserts the reloaded winner is identical (write ->
reload -> same winners), so the tuner never bit-rots headlessly.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
import time
from typing import Optional

from repro.core.conv_spec import ConvSpec
# shared with the planner so cache signatures can never drift from
# planner semantics (safe: repro.conv.plan never imports this module at
# module level — only lazily inside plan_conv)
from repro.conv.plan import _build_spec as _make_spec
from repro.conv.plan import _normalize_padding

CACHE_VERSION = 3

_DEFAULT_CACHE = os.path.join("~", ".cache", "repro_autotune.json")
_DEFAULT_BUDGET_MS = 2000.0
_DEFAULT_REPS = 3

AutotuneInfo = collections.namedtuple(
    "AutotuneInfo", ["hits", "misses", "fallbacks", "measured"])


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One (backend, schedule, block) point of the tuning space.

    ``us_per_call`` is the measured median (``None`` for cost-model
    fallbacks, which are never written to the cache).  ``source`` records
    provenance: ``"measured"`` | ``"cost-model"`` | ``"seeded"``.
    """
    backend: str
    schedule: str
    bm: Optional[int] = None           # Pallas CGEMM blocks
    bn: Optional[int] = None
    bk: Optional[int] = None
    dft_bt: Optional[int] = None       # dft_tile tile-batch block
    spectrum: str = "real"             # frequency layout (FFT pipelines)
    overlap: str = "off"               # sub-slab comm/compute overlap
    us_per_call: Optional[float] = None
    source: str = "measured"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def block_kwargs(self) -> dict:
        return dict(bm=self.bm, bn=self.bn, bk=self.bk, dft_bt=self.dft_bt)


# --------------------------------------------------------------------------
# Environment knobs
# --------------------------------------------------------------------------

def cache_path() -> str:
    """Tuning-cache file (env ``REPRO_AUTOTUNE_CACHE``)."""
    return os.path.expanduser(
        os.environ.get("REPRO_AUTOTUNE_CACHE", _DEFAULT_CACHE))


def autotune_enabled() -> bool:
    """Whether ``tune`` may *measure* (env ``REPRO_AUTOTUNE``); cache hits
    are served either way."""
    return os.environ.get("REPRO_AUTOTUNE", "1").strip().lower() \
        not in ("0", "false", "off", "no")


def budget_ms() -> float:
    try:
        return float(os.environ.get("REPRO_AUTOTUNE_BUDGET_MS",
                                    _DEFAULT_BUDGET_MS))
    except ValueError:
        return _DEFAULT_BUDGET_MS


def _env_reps() -> int:
    try:
        return max(1, int(os.environ.get("REPRO_AUTOTUNE_REPS",
                                         _DEFAULT_REPS)))
    except ValueError:
        return _DEFAULT_REPS


# --------------------------------------------------------------------------
# Persistent cache store
# --------------------------------------------------------------------------

class TuningCache:
    """JSON-file-backed key -> ``TunedConfig`` store (write-through,
    atomic replace; tolerant of a missing/corrupt/old-version file)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._entries: dict = self._load()

    def _load(self) -> dict:
        try:
            with open(self.path) as fh:
                data = json.load(fh)
            if not isinstance(data, dict) \
                    or data.get("version") != CACHE_VERSION:
                return {}
            entries = data.get("entries", {})
            return {k: TunedConfig.from_json(v)
                    for k, v in entries.items() if isinstance(v, dict)}
        except (OSError, ValueError, TypeError):
            return {}

    def get(self, key: str) -> Optional[TunedConfig]:
        with self._lock:
            return self._entries.get(key)

    def put(self, key: str, cfg: TunedConfig) -> None:
        with self._lock:
            self._entries[key] = cfg
            self._flush()

    def _flush(self) -> None:
        payload = {"version": CACHE_VERSION,
                   "entries": {k: v.to_json()
                               for k, v in sorted(self._entries.items())}}
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


_lock = threading.RLock()
_stores: dict = {}                      # resolved path -> TuningCache
_hits = _misses = _fallbacks = _measured = 0


def _store() -> TuningCache:
    path = cache_path()
    with _lock:
        store = _stores.get(path)
        if store is None:
            store = _stores[path] = TuningCache(path)
        return store


def autotune_info() -> AutotuneInfo:
    with _lock:
        return AutotuneInfo(_hits, _misses, _fallbacks, _measured)


def reset() -> None:
    """Drop the in-memory store and counters (cache *files* are kept —
    the next ``tune`` re-reads them from disk)."""
    global _hits, _misses, _fallbacks, _measured
    with _lock:
        _stores.clear()
        _hits = _misses = _fallbacks = _measured = 0


# --------------------------------------------------------------------------
# Cache keys
# --------------------------------------------------------------------------

def _device_kind() -> str:
    try:
        import jax
        return str(jax.devices()[0].device_kind).replace("|", "/")
    except Exception:
        return "unknown"


def _jax_version() -> str:
    import jax
    return jax.__version__


def _mesh_signature(mesh) -> str:
    if mesh is None:
        return "none"
    axes = ",".join(f"{a}:{n}" for a, n in mesh.shape.items())
    ids = ",".join(str(d.id) for d in mesh.devices.flat)
    return f"{axes};dev[{ids}]"


def _dtype_name(dtype) -> str:
    if dtype is None:
        return "none"
    try:
        import numpy as np
        return np.dtype(dtype).name
    except TypeError:
        return str(dtype)


def spec_signature(x_shape, k_shape, *, padding=(0, 0), delta: int = 16,
                   schedule: str = "auto", mesh=None, three_m: bool = True,
                   compute_dtype=None, data_axis: str = "data",
                   model_axis: str = "model",
                   replicate_kernel_transform: bool = False,
                   spectrum: str = "auto", overlap: str = "off",
                   bm=None, bn=None, bk=None, dft_bt=None) -> str:
    """Device-independent part of the cache key: the problem + the
    constraints the caller put on the tuner (requested schedule, mesh,
    precision, kernel-transform placement, requested spectrum, pinned
    blocks).  Two calls that could legally get different winners must get
    different signatures — a pin-constrained sweep must never answer for
    an unconstrained one."""
    pad = _normalize_padding(padding)
    return (f"v{CACHE_VERSION}"
            f"|x={tuple(map(int, x_shape))}|k={tuple(map(int, k_shape))}"
            f"|pad={pad}|delta={int(delta)}|sched={schedule}"
            f"|mesh={_mesh_signature(mesh)}|3m={int(bool(three_m))}"
            f"|dtype={_dtype_name(compute_dtype)}"
            f"|axes={data_axis},{model_axis}"
            f"|rkt={int(bool(replicate_kernel_transform))}"
            f"|spec={spectrum}|ov={overlap}"
            f"|pins={bm},{bn},{bk},{dft_bt}")


def cache_key(x_shape, k_shape, **kwargs) -> str:
    """Full cache key: spec signature + device kind + jax version."""
    return (spec_signature(x_shape, k_shape, **kwargs)
            + f"|dev={_device_kind()}|jax={_jax_version()}")


# --------------------------------------------------------------------------
# Candidate generation
# --------------------------------------------------------------------------

def _clamp_edge(v: int) -> int:
    return max(8, min(128, v))


def _block_candidates(spec: ConvSpec) -> list:
    """(bm, bn, bk) candidates for the Pallas CGEMM: the rounded default
    plus a half- and double-sized variant (clamped to the 8..128 edges)."""
    from repro.kernels.cgemm.ops import default_blocks
    base = default_blocks(spec.M, spec.Cout, spec.C)
    cands = [(None, None, None)]
    for f in (0.5, 2.0):
        alt = tuple(_clamp_edge(int(v * f)) for v in base)
        if alt != base and alt not in cands:
            cands.append(alt)
    return cands


def _merge_pins(cand: TunedConfig, bm, bn, bk, dft_bt) -> TunedConfig:
    """User-pinned block values override candidate values."""
    return dataclasses.replace(
        cand,
        bm=bm if bm is not None else cand.bm,
        bn=bn if bn is not None else cand.bn,
        bk=bk if bk is not None else cand.bk,
        dft_bt=dft_bt if dft_bt is not None else cand.dft_bt)


def candidates(spec: ConvSpec, *, schedule: str = "auto", mesh=None,
               three_m: bool = True, spectrum: str = "auto",
               overlap: str = "off",
               bm=None, bn=None, bk=None, dft_bt=None) -> list:
    """Enumerate the tuning space, cost-model pick first (so a clamped
    budget still measures the sane default), Pallas configs last (interpret
    mode on CPU makes them the most expensive to time).

    ``spectrum="auto"`` adds a real-vs-complex frequency-layout axis for
    the FFT backends (the compact half-spectrum wins on bandwidth-bound
    geometries, the full spectrum can win when the packing gather
    dominates); ``direct`` has no spectrum and is tuned as ``"real"``
    only.  Pinning ``spectrum`` collapses the axis.

    ``overlap="auto"`` adds the sub-slab comm/compute-overlap axis
    (``off``/``slab:2``/``slab:4``) for the sharded FFT schedules; local
    schedules and ``direct`` have nothing to overlap and stay ``off``.
    Overlapped Pallas candidates are timed at default blocks only (the
    planner re-pins blocks against the sub-slab shape, so sweeping block
    variants per slab count would square the Pallas tail of the sweep)."""
    if schedule != "auto":
        scheds = [schedule]
    else:
        scheds = ["nfft", "wfft"] if mesh is not None else ["local"]
    spectra = ["real", "complex"] if spectrum == "auto" else [spectrum]
    out = []
    for sched in scheds:
        local = sched == "local"
        if local:
            ovs = ["off"] if overlap in ("auto", "off") else [overlap]
        elif overlap == "auto":
            ovs = ["off", "slab:2", "slab:4"]
        else:
            ovs = [overlap]
        backends = (["direct", "fft-xla", "fft-pallas"] if local
                    else ["fft-xla", "fft-pallas"])
        for be in backends:
            if be == "direct":
                # the direct pipeline never builds a spectrum; a pinned
                # spectrum="complex" sweep excludes it (plan_conv rejects
                # the pair)
                if "real" in spectra:
                    out.append(TunedConfig(be, sched, spectrum="real"))
                continue
            for spc in spectra:
                for ov in ovs:
                    if be != "fft-pallas":
                        out.append(TunedConfig(be, sched, spectrum=spc,
                                               overlap=ov))
                        continue
                    if spc != "real" or ov != "off":
                        # complex Pallas takes the composed stage-4 path
                        # (no fused tail) and overlapped Pallas re-pins
                        # blocks per sub-slab — time only the
                        # default-block point
                        out.append(TunedConfig(be, sched, spectrum=spc,
                                               overlap=ov))
                        continue
                    bts = [None, 64] if local else [None]
                    for blocks in _block_candidates(spec):
                        for bt in bts:
                            out.append(TunedConfig(be, sched, *blocks,
                                                   dft_bt=bt, spectrum=spc,
                                                   overlap=ov))
    out = [_merge_pins(c, bm, bn, bk, dft_bt) for c in out]
    # dedupe (pins can collapse block variants) preserving order
    seen, uniq = set(), []
    for c in out:
        key = (c.backend, c.schedule, c.bm, c.bn, c.bk, c.dft_bt,
               c.spectrum, c.overlap)
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    # cost-model pick first (``_auto_backend`` never picks Pallas, so the
    # pick is always a single candidate), Pallas variants last
    pick = _cost_model_pick(spec, scheds[0], three_m)
    uniq.sort(key=lambda c: 0 if ((c.backend, c.schedule) == pick
                                  and c.spectrum == "real"
                                  and c.overlap == "off")
              else 1 if c.backend != "fft-pallas" else 2)
    return uniq


def _cost_model_pick(spec: ConvSpec, sched: str, three_m: bool) -> tuple:
    from repro.conv.plan import _auto_backend
    if sched != "local":
        return ("fft-xla", sched)
    return (_auto_backend(spec, three_m), sched)


# --------------------------------------------------------------------------
# Timing harness
# --------------------------------------------------------------------------

def measure_us(fn, *args, reps: int = _DEFAULT_REPS, **kwargs) -> float:
    """Warmup (compile) once, then median-of-``reps`` wall microseconds."""
    import jax
    jax.block_until_ready(fn(*args, **kwargs))
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def _measure_candidate(cand: TunedConfig, x_shape, k_shape, *, padding,
                       delta, mesh, three_m, compute_dtype, data_axis,
                       model_axis, replicate_kernel_transform,
                       reps) -> float:
    """Time one candidate through the real planner with a representative
    bias+relu epilogue (exercises the fused ``dft_tile`` tail, so
    ``dft_bt`` is a measured axis)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.conv.epilogue import Epilogue
    from repro.conv.plan import plan_conv
    plan = plan_conv(x_shape, k_shape, padding=padding, delta=delta,
                     backend=cand.backend, schedule=cand.schedule,
                     mesh=mesh, three_m=three_m, bm=cand.bm, bn=cand.bn,
                     bk=cand.bk, dft_bt=cand.dft_bt,
                     spectrum=cand.spectrum, overlap=cand.overlap,
                     compute_dtype=compute_dtype, data_axis=data_axis,
                     model_axis=model_axis,
                     replicate_kernel_transform=replicate_kernel_transform,
                     epilogue=Epilogue(bias=True, activation="relu"),
                     cache=False)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
    k = jnp.asarray(rng.standard_normal(k_shape), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k_shape[0],)), jnp.float32)
    return measure_us(plan, x, k, reps=reps, bias=b)


# --------------------------------------------------------------------------
# The tuner
# --------------------------------------------------------------------------

def _cost_model_config(spec: ConvSpec, schedule: str, mesh, three_m,
                       spectrum, overlap, bm, bn, bk, dft_bt) -> TunedConfig:
    if schedule == "auto":
        schedule = "nfft" if mesh is not None else "local"
    backend, _ = _cost_model_pick(spec, schedule, three_m)
    if spectrum == "auto" or backend == "direct":
        spectrum = "real"               # compact layout is the engine default
    if overlap == "auto":
        overlap = "off"                 # the cost model never bets on overlap
    return TunedConfig(backend, schedule, bm=bm, bn=bn, bk=bk,
                       dft_bt=dft_bt, spectrum=spectrum, overlap=overlap,
                       us_per_call=None, source="cost-model")


def tune(spec, k_shape=None, *, padding=None, delta: Optional[int] = None,
         schedule: str = "auto", mesh=None, three_m: bool = True,
         compute_dtype=None, data_axis: str = "data",
         model_axis: str = "model",
         replicate_kernel_transform: bool = False,
         spectrum: str = "auto", overlap: str = "off",
         bm=None, bn=None, bk=None, dft_bt=None,
         budget: Optional[float] = None,
         reps: Optional[int] = None) -> TunedConfig:
    """Return the winning config for this spec: warm-cache hit, measured
    sweep, or cost-model fallback (measurement disabled / every candidate
    failed), in that order.  Only measured winners are persisted — a
    cost-model fallback stays cold so enabling measurement later re-tunes.

    ``spec`` is the same first positional ``plan_conv`` takes: either a
    ``ConvSpec`` (geometry + padding + delta in one object) or the input
    shape ``(B, C, H, W)`` with ``k_shape``/``padding``/``delta`` given
    separately.
    """
    global _hits, _misses, _fallbacks, _measured
    if isinstance(spec, ConvSpec):
        if k_shape is not None or padding is not None or delta is not None:
            raise TypeError(
                "tune(spec, ...): a ConvSpec already carries k_shape/"
                "padding/delta — pass them only with the shape-tuple form")
        x_shape = (spec.B, spec.C, spec.H, spec.W)
        k_shape = (spec.Cout, spec.C, spec.kh, spec.kw)
        padding = (spec.pad_h, spec.pad_w)
        delta = spec.delta
    else:
        if k_shape is None:
            raise TypeError(
                "tune(x_shape, k_shape, ...): k_shape is required with "
                "the shape-tuple form (or pass a ConvSpec)")
        x_shape = spec
        padding = (0, 0) if padding is None else padding
        delta = 16 if delta is None else delta
    x_shape = tuple(map(int, x_shape))
    k_shape = tuple(map(int, k_shape))
    padding = _normalize_padding(padding)
    key_kwargs = dict(padding=padding, delta=delta, schedule=schedule,
                      mesh=mesh, three_m=three_m,
                      compute_dtype=compute_dtype, data_axis=data_axis,
                      model_axis=model_axis,
                      replicate_kernel_transform=replicate_kernel_transform,
                      spectrum=spectrum, overlap=overlap,
                      bm=bm, bn=bn, bk=bk, dft_bt=dft_bt)
    key = cache_key(x_shape, k_shape, **key_kwargs)
    store = _store()
    hit = store.get(key)
    if hit is not None:
        with _lock:
            _hits += 1
        return hit

    spec = _make_spec(x_shape, k_shape, padding, delta)
    if not autotune_enabled():
        with _lock:
            _fallbacks += 1
        return _cost_model_config(spec, schedule, mesh, three_m,
                                  spectrum, overlap, bm, bn, bk, dft_bt)
    with _lock:
        _misses += 1

    cands = candidates(spec, schedule=schedule, mesh=mesh, three_m=three_m,
                       spectrum=spectrum, overlap=overlap,
                       bm=bm, bn=bn, bk=bk, dft_bt=dft_bt)
    budget = budget_ms() if budget is None else float(budget)
    reps = _env_reps() if reps is None else max(1, int(reps))
    best = None
    t0 = time.perf_counter()
    for i, cand in enumerate(cands):
        if i > 0 and (time.perf_counter() - t0) * 1e3 > budget:
            break
        try:
            us = _measure_candidate(
                cand, x_shape, k_shape, padding=padding, delta=delta,
                mesh=mesh, three_m=three_m, compute_dtype=compute_dtype,
                data_axis=data_axis, model_axis=model_axis,
                replicate_kernel_transform=replicate_kernel_transform,
                reps=reps)
        except Exception:
            continue                    # infeasible candidate (skip)
        if best is None or us < best.us_per_call:
            best = dataclasses.replace(cand, us_per_call=us,
                                       source="measured")
    if best is None:
        with _lock:
            _fallbacks += 1
        return _cost_model_config(spec, schedule, mesh, three_m,
                                  spectrum, overlap, bm, bn, bk, dft_bt)
    with _lock:
        _measured += 1
    store.put(key, best)
    return best


def lookup(x_shape, k_shape, **key_kwargs) -> Optional[TunedConfig]:
    """Warm-cache lookup only (no measurement, no fallback)."""
    return _store().get(cache_key(x_shape, k_shape, **key_kwargs))


def seed(x_shape, k_shape, config: TunedConfig, **key_kwargs) -> str:
    """Force a winner into the cache (tests / pre-baked fleet configs);
    returns the cache key it was stored under."""
    key = cache_key(x_shape, k_shape, **key_kwargs)
    _store().put(key, config)
    return key


# --------------------------------------------------------------------------
# CLI selfcheck (CI: cache write -> reload -> same winners)
# --------------------------------------------------------------------------

def _selfcheck(x_shape, k_shape, padding) -> int:
    print(f"autotune selfcheck: cache={cache_path()} "
          f"enabled={autotune_enabled()} budget={budget_ms():.0f}ms "
          f"dev={_device_kind()} jax={_jax_version()}")
    reset()
    w1 = tune(x_shape, k_shape, padding=padding)
    print(f"  first tune : {w1}")
    if not autotune_enabled():
        w2 = tune(x_shape, k_shape, padding=padding)
        assert w2 == w1, f"cost-model fallback not deterministic: {w2}"
        print("  measurement disabled; deterministic cost-model fallback OK")
        return 0
    assert w1.source == "measured", f"expected a measured winner, got {w1}"
    assert os.path.exists(cache_path()), "tuning cache file was not written"
    reset()                             # drop memory; force re-read of disk
    w2 = tune(x_shape, k_shape, padding=padding)
    print(f"  reloaded   : {w2}")
    assert w2 == w1, f"cache round-trip changed the winner: {w1} != {w2}"
    info = autotune_info()
    assert info.hits == 1 and info.misses == 0, \
        f"reload did not hit the cache: {info}"
    with open(cache_path()) as fh:
        raw = json.load(fh)
    assert raw.get("version") == CACHE_VERSION and raw.get("entries"), \
        "cache file is not round-trippable"
    print(f"  selfcheck OK: winner {w2.backend}/{w2.schedule} "
          f"@ {w2.us_per_call:.0f}us, {len(raw['entries'])} cache entries")
    return 0


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="repro conv autotuner (see repro.conv.autotune)")
    ap.add_argument("--selfcheck", action="store_true",
                    help="tune one small spec; assert the cache file "
                         "round-trips (write -> reload -> same winners)")
    ap.add_argument("--x-shape", type=int, nargs=4, default=(1, 4, 16, 16),
                    metavar=("B", "C", "H", "W"))
    ap.add_argument("--k-shape", type=int, nargs=4, default=(8, 4, 3, 3),
                    metavar=("CO", "C", "KH", "KW"))
    ap.add_argument("--padding", type=int, default=1)
    args = ap.parse_args(argv)
    if args.selfcheck:
        return _selfcheck(tuple(args.x_shape), tuple(args.k_shape),
                          args.padding)
    w = tune(tuple(args.x_shape), tuple(args.k_shape), padding=args.padding)
    print(w)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
