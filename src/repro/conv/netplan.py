"""Whole-network convolution planning (``plan_network`` / ``NetworkPlan``).

fbfft's lesson (Vasilache et al.) is that FFT convolution pays off when
evaluated *network-wide*, not per-layer: the planning, the kernel
transforms and the fused elementwise tails all amortize across the whole
model.  This module resolves every conv layer of a model in ONE pass
against the shared plan cache:

    net = plan_network([
        NetworkConv("conv1", x_shape, k_shape, padding=1,
                    epilogue=Epilogue(bias=True, activation="relu")),
        ...
    ], backend="fft-xla", mesh=mesh, schedule="nfft")

    # serving: one invalidation sweep per weight update
    prepared = net.prepare(params, weights_version=step)
    y = prepared["conv1"](x, bias=params["conv1/bias"])

    # fleet cold-start: build once, deploy many (repro.conv.export)
    net.export("plans.rpa", params=params, weights_version=step)

``NetworkPlan.prepare`` runs each layer's kernel transform exactly once
per ``weights_version`` (repeat calls under the same version hit the
prepared cache; a new version after a weight update re-transforms
everything in one sweep), which is the serving lifecycle the ROADMAP
north-star wants.  ``plan_network(make_layers, buckets=batches)`` plans
one network per padded batch bucket (a ``BucketedNetworkPlan`` view) —
the serve engine's startup sweep.  The older ``prepare_all`` /
``plan_network_buckets`` / ``prepare_network_buckets`` /
``bucket_report`` spellings remain as DeprecationWarning shims.

``NetworkPlan.report()`` aggregates trace-time stage-op and collective
counts over the whole net, so "how many all_to_alls does one forward pass
pay" is a queryable number instead of per-layer archaeology; the counts
come from the static analyzer (``repro.conv.analyze``), which walks each
layer's equation tree rather than string-matching the jaxpr pretty
printer.  ``NetworkPlan.analyze()`` exposes the full per-layer profiles
and evaluates the invariant registry network-wide.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Any, Callable, Mapping, Optional, Sequence, Union

from repro.conv.epilogue import Epilogue
from repro.conv.plan import ConvPlan, PreparedConv, plan_conv


@dataclasses.dataclass(frozen=True)
class NetworkConv:
    """One conv layer of a model, as the network planner sees it.

    Geometry + the layer's fused epilogue; everything else (backend,
    schedule, mesh, precision) is shared network-wide via ``plan_network``
    kwargs, with ``overrides`` as the per-layer escape hatch (e.g. a tiny
    first layer that wants ``backend="direct"``).
    """
    name: str
    x_shape: tuple
    k_shape: tuple
    padding: Any = 0
    epilogue: Epilogue = Epilogue()
    overrides: tuple = ()        # (("backend", "direct"), ...) — hashable

    def plan_kwargs(self, shared: dict) -> dict:
        kw = dict(shared)
        kw.update(dict(self.overrides))
        kw["padding"] = self.padding
        kw["epilogue"] = self.epilogue
        return kw


@dataclasses.dataclass(frozen=True, eq=False)
class PreparedNetwork:
    """All layers of a ``NetworkPlan`` bound to prepared kernels.

    Mapping-like: ``prepared["conv1"](x, bias=...)``.  Every layer shares
    one ``weights_version``; re-prepare the network (not a layer) after a
    weight update.
    """
    layers: "collections.OrderedDict[str, PreparedConv]"
    weights_version: Any = None

    def __getitem__(self, name: str) -> PreparedConv:
        return self.layers[name]

    def __iter__(self):
        return iter(self.layers)

    def __len__(self):
        return len(self.layers)

    def items(self):
        return self.layers.items()


@dataclasses.dataclass(frozen=True, eq=False)
class NetworkProfile:
    """Per-layer static-analysis profiles for a whole network, plus the
    aggregate collective/stage totals one forward pass pays.  Certify
    every layer against the invariant registry with ``check()``."""
    layers: "collections.OrderedDict"          # name -> PlanProfile
    total_collectives: dict
    total_stage_counts: dict
    total_collective_bytes: int
    peak_live_bytes: int                       # max over layers

    def check(self):
        """Evaluate the invariant registry for every layer; returns a
        list of ``(layer_name, Violation)`` (empty = certified)."""
        out = []
        for name, profile in self.layers.items():
            out.extend((name, v) for v in profile.check().violations)
        return out

    def raise_if_failed(self) -> "NetworkProfile":
        bad = self.check()
        if bad:
            detail = "\n  ".join(f"{n}: {v}" for n, v in bad)
            raise AssertionError(
                f"plan-lint: network violates {len(bad)} invariant(s):"
                f"\n  {detail}")
        return self

    def to_dict(self) -> dict:
        return {
            "layers": {n: p.to_dict() for n, p in self.layers.items()},
            "total_collectives": dict(self.total_collectives),
            "total_stage_counts": dict(self.total_stage_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "peak_live_bytes": self.peak_live_bytes,
        }


@dataclasses.dataclass(frozen=True, eq=False)
class NetworkPlan:
    """Every conv layer of a model resolved to a ``ConvPlan`` in one pass.

    ``plans`` preserves layer order.  Same-geometry layers resolve to the
    *same* cached ``ConvPlan`` object (the shared plan cache deduplicates),
    so planning cost scales with distinct geometries, not layer count.
    """
    plans: "collections.OrderedDict[str, ConvPlan]"

    def __getitem__(self, name: str) -> ConvPlan:
        return self.plans[name]

    def __iter__(self):
        return iter(self.plans)

    def __len__(self):
        return len(self.plans)

    def items(self):
        return self.plans.items()

    @property
    def layer_names(self) -> tuple:
        return tuple(self.plans)

    # ---- serving ----------------------------------------------------------
    def prepare(self, params: Mapping[str, Any], *,
                weights_version=None) -> PreparedNetwork:
        """Prepare every layer's kernel under one ``weights_version``.

        ``params`` maps layer name -> kernel array (extra keys — biases,
        dense weights — are ignored, so a model's full param dict works).
        The kernel transform runs exactly once per layer per version:
        repeat calls with the same version return memoized
        ``PreparedConv`` objects from the prepared cache; a new version is
        one invalidation sweep re-transforming the whole net.
        """
        missing = [n for n in self.plans if n not in params]
        if missing:
            raise ValueError(
                f"prepare: params missing kernels for layers {missing}")
        layers = collections.OrderedDict(
            (name, plan.prepare(params[name],
                                weights_version=weights_version))
            for name, plan in self.plans.items())
        return PreparedNetwork(layers=layers,
                               weights_version=weights_version)

    def prepare_all(self, params: Mapping[str, Any], *,
                    weights_version=None) -> PreparedNetwork:
        """Deprecated spelling of ``NetworkPlan.prepare``."""
        warnings.warn(
            "NetworkPlan.prepare_all is deprecated; use "
            "NetworkPlan.prepare(params, weights_version=...)",
            DeprecationWarning, stacklevel=2)
        return self.prepare(params, weights_version=weights_version)

    def export(self, path: str, params: Optional[Mapping[str, Any]] = None,
               *, weights_version=None) -> str:
        """AOT-export this network to a plan artifact
        (``repro.conv.export``): every layer's jit lowered through
        ``jax.export`` plus its resolved config and plan-lint
        fingerprint.  With ``params`` the artifact is *prepared* (the
        transformed kernel slabs ride along under ``weights_version``);
        ``load_network(path)`` rehydrates it on a fresh worker with zero
        retracing."""
        from repro.conv.export import export_network
        return export_network(self, path, params=params,
                              weights_version=weights_version)

    # ---- introspection ----------------------------------------------------
    def tuning_report(self) -> dict:
        """Per-layer autotune winners after a ``backend="tuned"`` planning
        sweep: the resolved (backend, schedule, blocks) of every layer,
        plus the measured timing/provenance when the tuning cache has an
        entry for the layer's geometry (``us_per_call`` is ``None`` for
        layers resolved by the cost model or planned with a non-tuned
        backend)."""
        from repro.conv import autotune
        out = {}
        for name, plan in self.plans.items():
            cfg = None
            for sched_req in (plan.schedule, "auto"):
                for ov_req in (plan.overlap, "auto"):
                    c = autotune.lookup(
                        plan.x_shape, plan.k_shape, padding=plan.padding,
                        delta=plan.spec.delta, schedule=sched_req,
                        mesh=plan.mesh, three_m=plan.three_m,
                        compute_dtype=plan.compute_dtype,
                        data_axis=plan.data_axis,
                        model_axis=plan.model_axis,
                        replicate_kernel_transform=
                        plan.replicate_kernel_transform,
                        overlap=ov_req)
                    # only attribute a timing that describes THIS plan's
                    # resolved config — the cache may hold a different
                    # request's winner for the same geometry
                    if c is not None and (
                            c.backend, c.schedule, c.bm, c.bn, c.bk,
                            c.dft_bt, c.overlap
                    ) == (plan.backend, plan.schedule, plan.bm, plan.bn,
                          plan.bk, plan.dft_bt, plan.overlap):
                        cfg = c
                        break
                if cfg is not None:
                    break
            out[name] = {
                "backend": plan.backend, "schedule": plan.schedule,
                "bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                "dft_bt": plan.dft_bt, "overlap": plan.overlap,
                "us_per_call": cfg.us_per_call if cfg else None,
                "source": cfg.source if cfg else "unmeasured",
            }
        return out

    def analyze(self) -> NetworkProfile:
        """Static analysis of every layer (``repro.conv.analyze``): the
        per-layer ``PlanProfile`` plus network totals.  Same-geometry
        layers sharing one plan are profiled once each so the totals
        reflect one full forward pass."""
        from repro.conv.analyze import analyze
        total_stages: collections.Counter = collections.Counter()
        total_coll: collections.Counter = collections.Counter()
        total_bytes = 0
        peak = 0
        profiles: "collections.OrderedDict" = collections.OrderedDict()
        for name, plan in self.plans.items():
            p = analyze(plan)
            profiles[name] = p
            total_stages.update(p.stage_counts)
            total_coll.update(p.collectives)
            total_bytes += p.collective_bytes
            peak = max(peak, p.peak_live_bytes)
        return NetworkProfile(
            layers=profiles, total_collectives=dict(total_coll),
            total_stage_counts=dict(total_stages),
            total_collective_bytes=total_bytes, peak_live_bytes=peak)

    def report(self) -> dict:
        """Aggregate trace-time stage-op and collective counts for one
        forward pass of the whole net (one-shot plans), plus cost-model
        FLOPs.  Counts come from the static analyzer walking each layer's
        traced equation tree (NOT from string-matching the jaxpr pretty
        printer), so the numbers reflect what actually executes, schedule
        by schedule."""
        net = self.analyze()
        per_layer = {}
        total_flops = 0
        for name, plan in self.plans.items():
            p = net.layers[name]
            flops = plan.flops()
            per_layer[name] = {
                "backend": plan.backend, "schedule": plan.schedule,
                "epilogue": plan.epilogue.describe(),
                "stage_counts": dict(p.stage_counts),
                "collectives": dict(p.collectives),
                "flops": flops,
            }
            total_flops += flops
        return {
            "layers": per_layer,
            "total_stage_counts": dict(net.total_stage_counts),
            "total_collectives": dict(net.total_collectives),
            "total_flops": total_flops,
            "n_layers": len(self.plans),
            "n_distinct_plans": len({id(p) for p in self.plans.values()}),
        }

    def describe(self) -> str:
        rep = self.report()
        lines = [f"NetworkPlan: {rep['n_layers']} layers, "
                 f"{rep['n_distinct_plans']} distinct plans, "
                 f"{rep['total_flops']:.3e} FLOPs/pass"]
        for name, r in rep["layers"].items():
            coll = ", ".join(f"{k}={v}" for k, v in r["collectives"].items()
                             if v) or "none"
            lines.append(
                f"  {name}: {r['backend']}/{r['schedule']} "
                f"epilogue={r['epilogue']} collectives: {coll}")
        t = rep["total_collectives"]
        lines.append(f"  total collectives/pass: "
                     f"all_to_all={t.get('all_to_all', 0)} "
                     f"psum={t.get('psum', 0)}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True, eq=False)
class BucketedNetworkPlan:
    """One ``NetworkPlan`` per padded batch-size bucket — the serve
    engine's startup sweep as a first-class view.  Mapping-like over
    ``bucket -> NetworkPlan``; ``prepare``/``export`` sweep every bucket
    under ONE ``weights_version``."""
    nets: "collections.OrderedDict[int, NetworkPlan]"

    def __getitem__(self, bucket: int) -> NetworkPlan:
        return self.nets[bucket]

    def __iter__(self):
        return iter(self.nets)

    def __len__(self):
        return len(self.nets)

    def items(self):
        return self.nets.items()

    def keys(self):
        return self.nets.keys()

    def values(self):
        return self.nets.values()

    def prepare(self, params: Mapping[str, Any], *,
                weights_version=None) -> "collections.OrderedDict":
        """``NetworkPlan.prepare`` for every bucket under ONE
        ``weights_version``: each distinct (plan, kernel) pair
        transforms once — buckets sharing a geometry hit the prepared
        cache — and a weight update is one sweep re-preparing all
        buckets under the next version."""
        return collections.OrderedDict(
            (b, net.prepare(params, weights_version=weights_version))
            for b, net in self.nets.items())

    def report(self) -> dict:
        """Cross-bucket dedupe and cost summary: how many *distinct*
        frozen plans the bucket set resolves to (the shared-cache dedupe
        the serve engine relies on), plus per-bucket layer counts and
        FLOPs/pass."""
        return _bucket_report(self.nets)

    def export(self, path: str,
               params: Optional[Mapping[str, Any]] = None, *,
               weights_version=None) -> str:
        """AOT-export every bucket's network into one plan artifact
        (labels ``b<batch>``); see ``repro.conv.export``."""
        from repro.conv.export import export_network
        return export_network(self, path, params=params,
                              weights_version=weights_version)


def plan_network(layers: Union[Sequence[NetworkConv], Callable], *,
                 buckets: Optional[Sequence[int]] = None,
                 backend: str = "auto",
                 schedule: str = "auto", mesh=None, delta: int = 16,
                 three_m: bool = True, compute_dtype=None,
                 data_axis: str = "data", model_axis: str = "model",
                 replicate_kernel_transform: bool = False,
                 spectrum: str = "auto",
                 overlap: str = "off"):
    """Resolve every conv layer of a model in one planning pass.

    All layers share the network-wide knobs given here (backend, schedule,
    mesh, precision); a ``NetworkConv.overrides`` tuple adjusts individual
    layers.  Resolution goes through the shared ``plan_conv`` cache, so
    same-geometry layers (and repeat ``plan_network`` calls) share frozen
    ``ConvPlan`` objects.

    With ``buckets=batches``, ``layers`` must instead be a callable
    ``make_layers(batch)`` returning the ``NetworkConv`` sequence for one
    padded batch size; the result is a ``BucketedNetworkPlan`` (one
    ``NetworkPlan`` per bucket, shared-cache dedupe across buckets) — the
    startup sweep of the continuous-batching serve engine.

    With ``backend="tuned"`` this is the whole-network tuning sweep: every
    *distinct* layer geometry is measured once (shared-cache dedupe covers
    repeats) and ``NetworkPlan.tuning_report()`` lists the per-layer
    winners.
    """
    shared = dict(backend=backend, schedule=schedule, mesh=mesh, delta=delta,
                  three_m=three_m, compute_dtype=compute_dtype,
                  data_axis=data_axis, model_axis=model_axis,
                  replicate_kernel_transform=replicate_kernel_transform,
                  spectrum=spectrum, overlap=overlap)
    if buckets is not None:
        if not callable(layers):
            raise TypeError(
                "plan_network(..., buckets=...) needs a make_layers(batch) "
                "callable, not a layer sequence")
        dupes = [b for b, c in collections.Counter(buckets).items()
                 if c > 1]
        if dupes:
            raise ValueError(f"duplicate bucket batch sizes: {dupes}")
        nets = collections.OrderedDict(
            (int(b), plan_network(layers(int(b)), **shared))
            for b in buckets)
        return BucketedNetworkPlan(nets=nets)
    if callable(layers):
        raise TypeError(
            "plan_network got a callable layer factory; pass buckets= "
            "to plan per batch bucket, or the layer sequence itself")
    names = [l.name for l in layers]
    dupes = [n for n, c in collections.Counter(names).items() if c > 1]
    if dupes:
        raise ValueError(f"duplicate layer names: {dupes}")
    plans = collections.OrderedDict(
        (l.name, plan_conv(l.x_shape, l.k_shape, **l.plan_kwargs(shared)))
        for l in layers)
    return NetworkPlan(plans=plans)


def _bucket_report(nets: Mapping[Any, NetworkPlan]) -> dict:
    """Cross-bucket dedupe/cost summary over any label -> NetworkPlan
    mapping (shared by ``BucketedNetworkPlan.report`` and the serve
    engine's label-keyed view)."""
    distinct = {id(p) for net in nets.values()
                for p in net.plans.values()}
    per_bucket = {
        b: {"n_layers": len(net),
            "flops_per_pass": sum(p.flops() for p in net.plans.values())}
        for b, net in nets.items()}
    total_layers = sum(len(net) for net in nets.values())
    return {
        "n_buckets": len(nets),
        "n_layer_plans": total_layers,
        "n_distinct_plans": len(distinct),
        "dedupe_ratio": (len(distinct) / total_layers if total_layers
                         else 1.0),
        "buckets": per_bucket,
    }


# --------------------------------------------------------------------------
# Deprecated bucket-helper shims (pre-BucketedNetworkPlan spellings)
# --------------------------------------------------------------------------

def plan_network_buckets(make_layers, batches: Sequence[int],
                         **plan_kwargs) -> BucketedNetworkPlan:
    """Deprecated: use ``plan_network(make_layers, buckets=batches)``."""
    warnings.warn(
        "plan_network_buckets is deprecated; use "
        "plan_network(make_layers, buckets=batches)",
        DeprecationWarning, stacklevel=2)
    return plan_network(make_layers, buckets=batches, **plan_kwargs)


def prepare_network_buckets(nets: Mapping[int, NetworkPlan],
                            params: Mapping[str, Any], *,
                            weights_version=None
                            ) -> "collections.OrderedDict":
    """Deprecated: use ``BucketedNetworkPlan.prepare``."""
    warnings.warn(
        "prepare_network_buckets is deprecated; use "
        "BucketedNetworkPlan.prepare(params, weights_version=...)",
        DeprecationWarning, stacklevel=2)
    return collections.OrderedDict(
        (b, net.prepare(params, weights_version=weights_version))
        for b, net in nets.items())


def bucket_report(nets: Mapping[Any, NetworkPlan]) -> dict:
    """Deprecated: use ``BucketedNetworkPlan.report``."""
    warnings.warn(
        "bucket_report is deprecated; use BucketedNetworkPlan.report()",
        DeprecationWarning, stacklevel=2)
    return _bucket_report(nets)
