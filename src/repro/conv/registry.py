"""Backend / schedule registry for the plan-execute convolution engine.

A *backend* is a compute implementation (direct XLA conv, XLA FFT-conv,
Pallas-CGEMM FFT-conv, ...); a *schedule* is a data-movement strategy
(single-device ``local``, or the mesh-sharded ``nfft`` / ``wfft`` of the
paper).  Backends declare which schedules they support; ``plan_conv``
resolves a (backend, schedule) pair and the plan dispatches through this
registry at execute time.

Third-party backends register the same way the built-ins do:

    register_backend("my-backend", execute=my_fn, schedules=("local",))

where ``execute(plan, x, k) -> y`` receives the frozen ``ConvPlan``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """A registered convolution backend."""
    name: str
    execute: Callable          # (plan, x, k) -> (B, C', Ho, Wo)
    schedules: tuple           # schedule names this backend supports
    differentiable: tuple = () # schedules with working reverse-mode grads
    description: str = ""


@dataclasses.dataclass(frozen=True)
class ScheduleInfo:
    """A registered data-movement schedule."""
    name: str
    requires_mesh: bool
    description: str = ""


_BACKENDS: dict = {}
_SCHEDULES: dict = {}


def register_schedule(name: str, *, requires_mesh: bool,
                      description: str = "") -> ScheduleInfo:
    info = ScheduleInfo(name=name, requires_mesh=requires_mesh,
                        description=description)
    _SCHEDULES[name] = info
    return info


def register_backend(name: str, execute: Callable, *, schedules,
                     differentiable=(), description: str = "") -> BackendInfo:
    schedules = tuple(schedules)
    for s in schedules:
        if s not in _SCHEDULES:
            raise ValueError(
                f"backend {name!r} declares unknown schedule {s!r}; "
                f"register_schedule it first (known: {available_schedules()})")
    info = BackendInfo(name=name, execute=execute, schedules=schedules,
                       differentiable=tuple(differentiable),
                       description=description)
    _BACKENDS[name] = info
    return info


def get_backend(name: str) -> BackendInfo:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown conv backend {name!r}; available: "
            f"{available_backends()}") from None


def get_schedule(name: str) -> ScheduleInfo:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown conv schedule {name!r}; available: "
            f"{available_schedules()}") from None


def available_backends() -> tuple:
    return tuple(sorted(_BACKENDS))


def available_schedules() -> tuple:
    return tuple(sorted(_SCHEDULES))
