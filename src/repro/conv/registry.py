"""Backend / schedule registry for the plan-execute convolution engine.

A *backend* is a compute implementation (direct XLA conv, XLA FFT-conv,
Pallas-CGEMM FFT-conv, ...); a *schedule* is a data-movement strategy
(single-device ``local``, or the mesh-sharded ``nfft`` / ``wfft`` of the
paper).  Backends declare which schedules they support; ``plan_conv``
resolves a (backend, schedule) pair and the plan dispatches through this
registry at execute time.

A backend is registered in one of two forms:

  * **stage-pipeline** — ``pipeline_factory(plan) -> StagePipeline`` (see
    ``repro.conv.stages``).  Execution composes the stage graph, the plan
    gets ``prepare``/execute for free, and the backend is differentiable on
    *every* schedule it supports via the plan-level VJP
    (``repro.conv.autodiff``) — its ``differentiable`` set is derived, not
    declared.
  * **opaque execute** — ``execute(plan, x, k) -> y``.  Third-party
    backends register this way:

        register_backend("my-backend", execute=my_fn, schedules=("local",))

    Differentiability is whatever the callable supports: pass
    ``native_autodiff=True`` if jax can differentiate straight through it
    (like the built-in ``direct``), or declare an explicit
    ``differentiable=(...)`` subset.  Likewise fused-``Epilogue`` support
    is derived for stage pipelines but declared for opaque backends
    (``supports_epilogue=True`` + an ``execute(plan, x, k, bias=...,
    residual=...)`` signature); plans with a non-noop epilogue refuse to
    resolve to a backend that can't fuse it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """A registered convolution backend."""
    name: str
    schedules: tuple           # schedule names this backend supports
    execute: Optional[Callable] = None          # (plan, x, k) -> y (opaque)
    pipeline_factory: Optional[Callable] = None  # (plan) -> StagePipeline
    native_autodiff: bool = False  # jax differentiates execute directly
    declared_differentiable: tuple = ()          # opaque backends only
    declared_supports_epilogue: bool = False     # opaque backends only
    description: str = ""

    @property
    def differentiable(self) -> tuple:
        """Schedules with working reverse-mode grads — *derived*: every
        stage-pipeline backend gets the plan-level VJP on all its
        schedules, native-autodiff backends differentiate everywhere they
        execute, and only opaque backends fall back to their declaration."""
        if self.pipeline_factory is not None or self.native_autodiff:
            return self.schedules
        return self.declared_differentiable

    @property
    def epilogue_capable(self) -> bool:
        """Whether plans with a non-noop ``Epilogue`` may resolve to this
        backend — *derived* for stage pipelines (the stage graph fuses the
        epilogue into stage 4 on every schedule); opaque backends must
        declare ``supports_epilogue=True`` and accept
        ``execute(plan, x, k, bias=..., residual=...)``."""
        return self.pipeline_factory is not None \
            or self.declared_supports_epilogue

    def make_pipeline(self, plan):
        if self.pipeline_factory is None:
            raise ValueError(
                f"backend {self.name!r} is not a stage-pipeline backend")
        return self.pipeline_factory(plan)


@dataclasses.dataclass(frozen=True)
class ScheduleInfo:
    """A registered data-movement schedule."""
    name: str
    requires_mesh: bool
    description: str = ""


_BACKENDS: dict = {}
_SCHEDULES: dict = {}


def register_schedule(name: str, *, requires_mesh: bool,
                      description: str = "") -> ScheduleInfo:
    info = ScheduleInfo(name=name, requires_mesh=requires_mesh,
                        description=description)
    _SCHEDULES[name] = info
    return info


def register_backend(name: str, execute: Optional[Callable] = None, *,
                     schedules, pipeline_factory: Optional[Callable] = None,
                     native_autodiff: bool = False, differentiable=(),
                     supports_epilogue: bool = False,
                     description: str = "") -> BackendInfo:
    if (execute is None) == (pipeline_factory is None):
        raise ValueError(
            f"backend {name!r}: register exactly one of execute= or "
            "pipeline_factory=")
    schedules = tuple(schedules)
    for s in schedules:
        if s not in _SCHEDULES:
            raise ValueError(
                f"backend {name!r} declares unknown schedule {s!r}; "
                f"register_schedule it first (known: {available_schedules()})")
    info = BackendInfo(name=name, schedules=schedules, execute=execute,
                       pipeline_factory=pipeline_factory,
                       native_autodiff=native_autodiff,
                       declared_differentiable=tuple(differentiable),
                       declared_supports_epilogue=supports_epilogue,
                       description=description)
    _BACKENDS[name] = info
    return info


def get_backend(name: str) -> BackendInfo:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown conv backend {name!r}; available: "
            f"{available_backends()}") from None


def get_schedule(name: str) -> ScheduleInfo:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown conv schedule {name!r}; available: "
            f"{available_schedules()}") from None


def available_backends() -> tuple:
    return tuple(sorted(_BACKENDS))


def available_schedules() -> tuple:
    return tuple(sorted(_SCHEDULES))


def backend_schedule_pairs() -> tuple:
    """Every registered (backend, schedule) combination, in registry
    order.  This is the sweep surface of the static analyzer
    (``repro.conv.analyze --check``): a newly registered backend is
    automatically certified against the invariant registry on every
    schedule it declares."""
    return tuple((b, s) for b in available_backends()
                 for s in _BACKENDS[b].schedules)
