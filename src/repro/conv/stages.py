"""Composable stage graph for the FFT-convolution engine.

The paper's pipeline is four stage *ops* —

  1. input transform    I (B,C,H,W)    -> D (P, M, C)
  2. kernel transform   K (C',C,kh,kw) -> G (P, C, C')
  3. CGEMM              Z[p] = D[p] @ G[p]            (hot stage)
  4. output inverse     Z (P, M, C')   -> O (B,C',Ho,Wo)

— and a *schedule* is a composition of those ops with data movement in
between: ``local`` runs them back-to-back on one device, ``nfft`` places an
``all_to_all`` at each stage boundary (the paper's NUMA-aware tuple
partitioning), ``wfft`` leaves the contraction axis sharded and pays a
``psum`` inside stage 3.  This module defines the stage ops once (thin,
counted wrappers over ``repro.core.fftconv``) plus one pipeline class per
schedule.

Every pipeline accepts a plan-frozen ``Epilogue`` (bias add, activation,
residual add — see ``repro.conv.epilogue``) executed *inside* stage 4 on
the local output slab: zero extra collectives (the operands enter
``shard_map`` pre-sharded), zero extra stage-op invocations (the
elementwise tail rides the existing ``output_inverse`` op), and the work
happens before the f32 -> x.dtype cast.

Every pipeline exposes the prepare/execute split:

  ``prepare(plan, k)``   run stage 2 once, returning the transformed kernel
                         ``G`` in the exact layout execution consumes — for
                         the sharded schedules that is the *post-boundary*
                         layout, so prepared execution runs stage 2 AND
                         boundary all-to-all #2 zero times;
  ``execute(plan, x, G)``run stages 1/3/4 (+ remaining collectives) against
                         a prepared ``G``;
  ``full(plan, x, k)``   the one-shot path: stage 2 inline.

Every stage op takes a ``spectrum`` layout argument (see
``repro.core.fftconv``): ``"real"`` flows the compact Hermitian
half-spectrum (~0.51x the frequency points) through the whole graph —
the nfft boundary all-to-alls and the wfft hot psum pair move roughly
half the bytes of the ``"complex"`` full-spectrum twin.

Stage-op invocations are counted at trace time via the thread-safe
context manager::

    with stage_trace() as counts:
        jax.make_jaxpr(plan)(x, k)
    assert counts["cgemm"] == 1

Traces also record dtype facts as ``("cgemm_dtype", <dtype>)`` tuple keys
alongside the plain string op counts — the static analyzer reads these to
certify that ``compute_dtype`` actually reached the hot stage.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.conv_spec import ConvSpec
from repro.core import fftconv as F
from repro.core.cgemm import cgemm
from repro.conv.epilogue import Epilogue, apply_epilogue


# --------------------------------------------------------------------------
# Stage-op trace counters (thread-safe, context-managed)
# --------------------------------------------------------------------------

_tls = threading.local()                 # per-thread stack of active traces


def _count(name: str) -> None:
    for counter in getattr(_tls, "stack", ()):
        counter[name] += 1


@contextlib.contextmanager
def stage_trace():
    """Scoped, thread-local stage-op counter.

    Counts only the stage ops traced by *this* thread while the context is
    active, so concurrent planners/tracers don't bleed into each other.
    Nested traces each observe the ops traced inside them.
    """
    counts: collections.Counter = collections.Counter()
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(counts)
    try:
        yield counts
    finally:
        # remove by IDENTITY: ``with`` exits are LIFO, and equality-based
        # removal would pop the wrong Counter when two traces hold equal
        # contents (e.g. both still empty)
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is counts:
                del stack[i]
                break


# --------------------------------------------------------------------------
# Stage ops (counted)
# --------------------------------------------------------------------------

def stage_input_transform(x, spec: ConvSpec, spectrum: str = "rect"):
    _count("input_transform")
    return F.input_transform(x, spec, spectrum=spectrum)


def stage_kernel_transform(k, spec: ConvSpec, spectrum: str = "rect"):
    _count("kernel_transform")
    return F.kernel_transform(k, spec, spectrum=spectrum)


def stage_cgemm(Dr, Di, Gr, Gi, *, three_m: bool, cgemm_fn=None):
    _count("cgemm")
    # dtype-flow fact for the analyzer: which dtype the hot stage actually
    # consumed (tuple keys ride the same counters as the op counts)
    _count(("cgemm_dtype", str(jnp.result_type(Dr, Gr))))
    # shape fact: (M, N, K) of this invocation — the analyzer certifies
    # that every sub-slab of an overlapped plan resolves the SAME Pallas
    # block config (no per-slab re-padding)
    _count(("cgemm_shape",
            (int(Dr.shape[-2]), int(Gr.shape[-1]), int(Dr.shape[-1]))))
    mm = cgemm_fn if cgemm_fn is not None else functools.partial(
        cgemm, three_m=three_m)
    return mm(Dr, Di, Gr, Gi)


def stage_output_inverse(Zr, Zi, spec: ConvSpec, *, epilogue: Epilogue = None,
                         bias=None, residual=None, inverse_fn=None,
                         spectrum: str = "rect"):
    """Stage 4 with the fused elementwise epilogue.

    The epilogue rides inside this single stage op (the counter increments
    once, fused or not).  ``inverse_fn`` is a backend-supplied fused
    inverse+epilogue kernel ``(Zr, Zi, spec, epilogue, bias) -> y`` (the
    Pallas ``dft_tile`` tail) matched to the plan's spectrum layout; it
    cannot fold a residual — the residual lives in output layout, not tile
    layout — so residual epilogues fall back to the composed path.
    """
    _count("output_inverse")
    if (inverse_fn is not None and epilogue is not None
            and not epilogue.is_noop and not epilogue.residual):
        return inverse_fn(Zr, Zi, spec, epilogue, bias)
    y = F.output_inverse(Zr, Zi, spec, spectrum=spectrum)
    return apply_epilogue(y, epilogue, bias=bias, residual=residual)


def _boundary_a2a(Tr, Ti, axis_name, split, concat):
    """One nfft stage-boundary all-to-all (re/im pair, counted once)."""
    _count("boundary_a2a")
    Tr = jax.lax.all_to_all(Tr, axis_name, split, concat, tiled=True)
    Ti = jax.lax.all_to_all(Ti, axis_name, split, concat, tiled=True)
    return Tr, Ti


def _slab_a2a(Tr, Ti, axis_name, split, concat):
    """The boundary all-to-all as issued by the overlapped (sub-slab)
    path.  Functionally identical to ``_boundary_a2a`` — a separate
    module-level indirection so the ``overlap-oversend`` seeded violation
    can inflate per-slab collective bytes without touching the sequential
    twin the analyzer compares against."""
    return _boundary_a2a(Tr, Ti, axis_name, split, concat)


def _slab_psum(Zr, Zi, axis_name):
    """The wfft hot-stage all-reduce pair as issued by the overlapped
    (sub-slab) path; see ``_slab_a2a`` for why this is patchable."""
    return jax.lax.psum(Zr, axis_name), jax.lax.psum(Zi, axis_name)


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------

def _pad_axis(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _local_spec(spec: ConvSpec, b_loc: int, c_loc: int, co_loc: int):
    return ConvSpec(B=b_loc, C=c_loc, Cout=co_loc, H=spec.H, W=spec.W,
                    kh=spec.kh, kw=spec.kw, pad_h=spec.pad_h,
                    pad_w=spec.pad_w, delta=spec.delta)


def padded_sharded_spec(plan) -> ConvSpec:
    """The ConvSpec of the mesh-padded problem the sharded bodies see.

    Channel/batch axes are zero-padded up to mesh-axis multiples (e.g. VGG
    conv1.1's C=3); padded channels multiply zeros and are sliced away.
    """
    s = plan.spec
    n_data = plan.mesh.shape[plan.data_axis]
    n_model = plan.mesh.shape[plan.model_axis]
    return ConvSpec(
        B=s.B + (-s.B) % n_data, C=s.C + (-s.C) % n_model,
        Cout=s.Cout + (-s.Cout) % n_model, H=s.H, W=s.W, kh=s.kh, kw=s.kw,
        pad_h=s.pad_h, pad_w=s.pad_w, delta=s.delta)


def _maybe_cast(pair, dtype):
    if dtype is None:
        return pair
    return pair[0].astype(dtype), pair[1].astype(dtype)


def _slab_sizes(n: int, k: int) -> tuple:
    """Static batch sub-slab sizes for overlapped execution: ``k`` slabs
    covering ``n`` rows, the remainder spread over the leading slabs so
    sizes differ by at most one (k is clamped to n — never an empty
    slab)."""
    k = max(1, min(int(k), int(n)))
    base, rem = divmod(int(n), k)
    return tuple(base + (1 if i < rem else 0) for i in range(k))


def _slab_splits(x, sizes, axis=0):
    """Slice ``x`` into static sub-slabs of the given sizes along
    ``axis``."""
    out, start = [], 0
    for n in sizes:
        out.append(jax.lax.slice_in_dim(x, start, start + n, axis=axis))
        start += n
    return out


def _epilogue_operands(plan, bias, residual):
    """Pad + spec the epilogue operands for shard_map entry.

    Bias is C'-sharded over the model axis and the residual is sharded
    exactly like the output, so the epilogue costs ZERO collectives: every
    rank receives precisely the slab its local stage-4 output needs.
    """
    ep = plan.epilogue
    n_data = plan.mesh.shape[plan.data_axis]
    n_model = plan.mesh.shape[plan.model_axis]
    args, specs = [], []
    if ep.bias:
        args.append(_pad_axis(bias, 0, n_model))
        specs.append(P(plan.model_axis))
    if ep.residual:
        args.append(_pad_axis(_pad_axis(residual, 0, n_data), 1, n_model))
        specs.append(P(plan.data_axis, plan.model_axis, None, None))
    return tuple(args), tuple(specs)


def _unpack_epilogue_args(plan, ep_args):
    ep = plan.epilogue
    it = iter(ep_args)
    bias = next(it) if ep.bias else None
    residual = next(it) if ep.residual else None
    return bias, residual


# --------------------------------------------------------------------------
# local schedule
# --------------------------------------------------------------------------

class LocalPipeline:
    """Single device: stages back-to-back, no collectives.  The epilogue is
    fused into stage 4; ``inverse_fn`` (Pallas backend) fuses it into the
    tile-inverse kernel tail itself."""

    def __init__(self, cgemm_fn=None, inverse_fn=None):
        self.cgemm_fn = cgemm_fn
        self.inverse_fn = inverse_fn

    def prepare(self, plan, k):
        return stage_kernel_transform(k, plan.spec, plan.spectrum)

    def execute(self, plan, x, G, bias=None, residual=None):
        spec = plan.spec
        Dr, Di = stage_input_transform(x, spec, plan.spectrum)
        Gr, Gi = G
        Dr, Di = _maybe_cast((Dr, Di), plan.compute_dtype)
        Gr, Gi = _maybe_cast((Gr, Gi), plan.compute_dtype)
        Zr, Zi = stage_cgemm(Dr, Di, Gr, Gi, three_m=plan.three_m,
                             cgemm_fn=self.cgemm_fn)
        Zr, Zi = Zr.astype(jnp.float32), Zi.astype(jnp.float32)
        y = stage_output_inverse(Zr, Zi, spec, epilogue=plan.epilogue,
                                 bias=bias, residual=residual,
                                 inverse_fn=self.inverse_fn,
                                 spectrum=plan.spectrum)
        return y.astype(x.dtype)

    def full(self, plan, x, k, bias=None, residual=None):
        return self.execute(plan, x, self.prepare(plan, k), bias=bias,
                            residual=residual)


# --------------------------------------------------------------------------
# nfft schedule (the paper's NUMA-aware tuple partitioning)
# --------------------------------------------------------------------------

class NfftPipeline:
    """Transforms where the data lives; one all-to-all per stage boundary;
    collective-free hot CGEMM.  Prepared form: ``G`` in the post-boundary
    layout — global (P, C, C') with the P axis sharded over ``model`` — so
    prepared execution skips stage 2 and boundary a2a #2 entirely.  The
    epilogue runs inside the body on each rank's C'/N stage-4 slab."""

    def __init__(self, cgemm_fn=None, inverse_fn=None):
        self.cgemm_fn = cgemm_fn
        # inverse_fn is a local-schedule fusion (tile-kernel tail); the
        # sharded bodies fuse the epilogue at the stage level instead.

    # ---- bodies (per-device, under shard_map) -----------------------------

    def _body_full(self, x, k, *ep_args, plan, spec, n_model):
        """x: (B_loc, C_loc, H, W); k: C'-sharded (or replicated)."""
        Gr, Gi = self._stage2(k, plan, spec, n_model)
        return self._slabbed(x, Gr, Gi, ep_args, plan, spec, n_model)

    def _body_prepared(self, x, Gr, Gi, *ep_args, plan, spec, n_model):
        """x: (B_loc, C_loc, H, W); Gr/Gi: the local (P/N, C, C') slab."""
        return self._slabbed(x, Gr, Gi, ep_args, plan, spec, n_model)

    def _slabbed(self, x, Gr, Gi, ep_args, plan, spec, n_model):
        """Stages 1/3/4 against a boundary-layout G, in ``plan.num_slabs``
        batch sub-slabs.

        With ``overlap="off"`` (one slab) this is the sequential path.
        With ``overlap="slab:k"`` the batch is split into k static
        sub-slabs, double-buffered: the stage-1 transform AND boundary
        all-to-all #1 of slab i+1 are issued *before* the hot cgemm +
        boundary a2a #3 + stage-4 tail of slab i, so the async collective
        of one slab overlaps the compute of another under XLA's
        latency-hiding scheduler (``repro.launch.env`` sets the flags).
        The kernel-side work (stage 2 / boundary a2a #2) is shared by all
        slabs and never slabbed; total collective bytes are unchanged vs
        the sequential twin (each per-slab a2a moves 1/k of the rows).
        """
        bias, residual = _unpack_epilogue_args(plan, ep_args)
        sizes = _slab_sizes(x.shape[0], getattr(plan, "num_slabs", 1))
        if len(sizes) == 1:
            Dr, Di = self._stage1_and_boundary1(x, plan, spec)
            return self._hot_and_tail(x, Dr, Di, Gr, Gi, bias, residual,
                                      plan, spec, n_model)
        xs = _slab_splits(x, sizes)
        rs = _slab_splits(residual, sizes) if residual is not None \
            else [None] * len(xs)
        staged = self._stage1_and_boundary1(xs[0], plan, spec, slab=True)
        outs = []
        for i, xi in enumerate(xs):
            nxt = None
            if i + 1 < len(xs):
                # issue slab i+1's transform + boundary a2a before slab
                # i's hot stage consumes its own staged operands
                nxt = self._stage1_and_boundary1(xs[i + 1], plan, spec,
                                                 slab=True)
            outs.append(self._hot_and_tail(xi, *staged, Gr, Gi, bias,
                                           rs[i], plan, spec, n_model,
                                           slab=True))
            staged = nxt
        return jnp.concatenate(outs, axis=0)

    def _stage1_and_boundary1(self, x, plan, spec, slab=False):
        b_loc, c_loc = x.shape[0], x.shape[1]
        sp1 = _local_spec(spec, b_loc, c_loc, spec.Cout)
        Dr, Di = stage_input_transform(x, sp1, plan.spectrum)
        # The tiled all-to-all splits the P axis N ways: pad the frequency
        # list up to a model-axis multiple ONCE here (padded rows are zero,
        # flow inertly through the CGEMM, and stage 4 slices them off).
        n_model = plan.mesh.shape[plan.model_axis]
        Dr, Di = _pad_axis(Dr, 0, n_model), _pad_axis(Di, 0, n_model)
        if plan.compute_dtype is not None:
            # cast BEFORE the boundary a2a so the collective moves half the
            # bytes
            Dr, Di = _maybe_cast((Dr, Di), plan.compute_dtype)
        # Boundary a2a #1 (tuple partitioning): (P, M, C_loc) -> (P/N, M, C)
        a2a = _slab_a2a if slab else _boundary_a2a
        return a2a(Dr, Di, plan.model_axis, 0, 2)

    def _stage2(self, k, plan, spec, n_model):
        c_full = k.shape[1]
        sp2 = _local_spec(spec, spec.B, c_full, k.shape[0])
        if plan.replicate_kernel_transform:
            # Stage 2': full kernel transform on every rank, local P-slab
            # slice — removes boundary a2a #2 (beyond-paper optimization).
            Gr, Gi = stage_kernel_transform(k, sp2, plan.spectrum)
            Gr, Gi = _pad_axis(Gr, 0, n_model), _pad_axis(Gi, 0, n_model)
            p_loc = Gr.shape[0] // n_model
            idx = jax.lax.axis_index(plan.model_axis) * p_loc
            Gr = jax.lax.dynamic_slice_in_dim(Gr, idx, p_loc, axis=0)
            Gi = jax.lax.dynamic_slice_in_dim(Gi, idx, p_loc, axis=0)
            return Gr, Gi
        # Stage 2: transform the local C'_loc kernels -> G (P, C, C'_loc)
        Gr, Gi = stage_kernel_transform(k, sp2, plan.spectrum)
        Gr, Gi = _pad_axis(Gr, 0, n_model), _pad_axis(Gi, 0, n_model)
        # Boundary a2a #2: (P, C, C'_loc) -> (P/N, C, C')
        return _boundary_a2a(Gr, Gi, plan.model_axis, 0, 2)

    def _hot_and_tail(self, x, Dr, Di, Gr, Gi, bias, residual, plan, spec,
                      n_model, slab=False):
        b_loc, c_full = x.shape[0], spec.C
        # Stage 3 (HOT): local P/N-slab complex GEMM — no collectives.
        Gr, Gi = _maybe_cast((Gr, Gi), plan.compute_dtype)
        Zr, Zi = stage_cgemm(Dr, Di, Gr, Gi, three_m=plan.three_m,
                             cgemm_fn=self.cgemm_fn)  # f32 accumulation
        if plan.compute_dtype is not None:
            Zr, Zi = _maybe_cast((Zr, Zi), plan.compute_dtype)
        # Boundary a2a #3 (gather tuples for the inverse):
        # (P/N, M_loc, C') -> (P, M_loc, C'/N)
        a2a = _slab_a2a if slab else _boundary_a2a
        Zr, Zi = a2a(Zr, Zi, plan.model_axis, 2, 0)
        Zr, Zi = Zr.astype(jnp.float32), Zi.astype(jnp.float32)
        # Stage 4: each model rank inverts its C'/N output-channel slab and
        # applies the fused epilogue on that 1/N slab (pre-sharded operands,
        # zero collectives), before the output dtype cast.
        sp4 = _local_spec(spec, b_loc, c_full, spec.Cout // n_model)
        return stage_output_inverse(Zr, Zi, sp4, epilogue=plan.epilogue,
                                    bias=bias, residual=residual,
                                    spectrum=plan.spectrum)

    # ---- global entry points ----------------------------------------------

    def prepare(self, plan, k):
        """Stage 2 (+ its boundary movement), once: global (P, C, C').

        The P axis is padded up to a model-axis multiple so the prepared
        slab enters shard_map P-sharded (matching the post-boundary layout
        the a2a padding produces on the inline path).
        """
        spec = padded_sharded_spec(plan)
        n_model = plan.mesh.shape[plan.model_axis]
        kp = _pad_axis(_pad_axis(k, 0, n_model), 1, n_model)
        Gr, Gi = stage_kernel_transform(kp, spec, plan.spectrum)
        return _pad_axis(Gr, 0, n_model), _pad_axis(Gi, 0, n_model)

    def execute(self, plan, x, G, bias=None, residual=None):
        spec = padded_sharded_spec(plan)
        mesh = plan.mesh
        n_model = mesh.shape[plan.model_axis]
        xp = _pad_axis(_pad_axis(x, 0, mesh.shape[plan.data_axis]), 1,
                       n_model)
        Gr, Gi = G
        ep_args, ep_specs = _epilogue_operands(plan, bias, residual)
        body = functools.partial(self._body_prepared, plan=plan, spec=spec,
                                 n_model=n_model)
        in_specs = (P(plan.data_axis, plan.model_axis, None, None),
                    P(plan.model_axis, None, None),    # G: P-slab per rank
                    P(plan.model_axis, None, None),
                    *ep_specs)
        out_spec = P(plan.data_axis, plan.model_axis, None, None)
        y = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_spec)(xp, Gr, Gi, *ep_args)
        return y[:plan.spec.B, :plan.spec.Cout].astype(x.dtype)

    def full(self, plan, x, k, bias=None, residual=None):
        spec = padded_sharded_spec(plan)
        mesh = plan.mesh
        n_model = mesh.shape[plan.model_axis]
        xp = _pad_axis(_pad_axis(x, 0, mesh.shape[plan.data_axis]), 1,
                       n_model)
        kp = _pad_axis(_pad_axis(k, 0, n_model), 1, n_model)
        ep_args, ep_specs = _epilogue_operands(plan, bias, residual)
        body = functools.partial(self._body_full, plan=plan, spec=spec,
                                 n_model=n_model)
        k_spec = P(None, None, None, None) \
            if plan.replicate_kernel_transform \
            else P(plan.model_axis, None, None, None)   # k: C' sharded
        in_specs = (P(plan.data_axis, plan.model_axis, None, None), k_spec,
                    *ep_specs)
        out_spec = P(plan.data_axis, plan.model_axis, None, None)
        y = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_spec)(xp, kp, *ep_args)
        return y[:plan.spec.B, :plan.spec.Cout].astype(x.dtype)


# --------------------------------------------------------------------------
# wfft schedule (Wang et al. baseline)
# --------------------------------------------------------------------------

class WfftPipeline:
    """No tuple partitioning: the CGEMM contracts a channel axis spread over
    ``model``, so a psum (all-reduce of the whole Z) sits inside the hot
    stage.  Prepared form: global (P, C, C') with the C axis sharded.  The
    epilogue is fused into each rank's C'/N stage-4 slab like nfft."""

    def __init__(self, cgemm_fn=None, inverse_fn=None):
        self.cgemm_fn = cgemm_fn

    def _body(self, x, Gr, Gi, *ep_args, plan, spec, n_model):
        """x: (B_loc, C_loc, H, W); Gr/Gi: the local (P, C_loc, C') slab.

        With ``overlap="slab:k"`` the batch is split into k static
        sub-slabs, double-buffered: the stage-1 transform + partial cgemm
        of slab i+1 are issued *before* the hot-stage psum + stage-4 tail
        of slab i, so the all-reduce of one slab overlaps the compute of
        another (each per-slab psum moves 1/k of the rows — total bytes
        unchanged vs the sequential twin).
        """
        bias, residual = _unpack_epilogue_args(plan, ep_args)
        Gr, Gi = _maybe_cast((Gr, Gi), plan.compute_dtype)
        sizes = _slab_sizes(x.shape[0], getattr(plan, "num_slabs", 1))
        if len(sizes) == 1:
            return self._psum_and_tail(
                x, *self._partial_z(x, Gr, Gi, plan, spec), bias, residual,
                plan, spec, n_model)
        xs = _slab_splits(x, sizes)
        rs = _slab_splits(residual, sizes) if residual is not None \
            else [None] * len(xs)
        staged = self._partial_z(xs[0], Gr, Gi, plan, spec)
        outs = []
        for i, xi in enumerate(xs):
            nxt = None
            if i + 1 < len(xs):
                # issue slab i+1's transform + partial cgemm before slab
                # i's hot-stage all-reduce
                nxt = self._partial_z(xs[i + 1], Gr, Gi, plan, spec)
            outs.append(self._psum_and_tail(xi, *staged, bias, rs[i],
                                            plan, spec, n_model, slab=True))
            staged = nxt
        return jnp.concatenate(outs, axis=0)

    def _partial_z(self, x, Gr, Gi, plan, spec):
        """Stage 1 + the partial (C-sharded contraction) cgemm for one
        batch slab; G enters already cast to compute_dtype."""
        sp1 = _local_spec(spec, x.shape[0], x.shape[1], spec.Cout)
        Dr, Di = stage_input_transform(x, sp1, plan.spectrum)  # (P, M, C_loc)
        Dr, Di = _maybe_cast((Dr, Di), plan.compute_dtype)
        Zr, Zi = stage_cgemm(Dr, Di, Gr, Gi, three_m=plan.three_m,
                             cgemm_fn=self.cgemm_fn)  # partial sums, f32 acc
        if plan.compute_dtype is not None:
            # cast BEFORE the hot-stage psum so the all-reduce moves half
            # the bytes (parity with the nfft boundary-a2a cast)
            Zr, Zi = _maybe_cast((Zr, Zi), plan.compute_dtype)
        return Zr, Zi

    def _psum_and_tail(self, x, Zr, Zi, bias, residual, plan, spec, n_model,
                       slab=False):
        # HOT-STAGE collective: all-reduce the full Z across the model axis.
        if slab:
            Zr, Zi = _slab_psum(Zr, Zi, plan.model_axis)
        else:
            Zr = jax.lax.psum(Zr, plan.model_axis)
            Zi = jax.lax.psum(Zi, plan.model_axis)
        Zr, Zi = Zr.astype(jnp.float32), Zi.astype(jnp.float32)

        # Each rank inverts its C'/N slice (avoids duplicate stage-4 work)
        # and applies the fused epilogue on that slab only.
        co_loc = spec.Cout // n_model
        idx = jax.lax.axis_index(plan.model_axis)
        Zr = jax.lax.dynamic_slice_in_dim(Zr, idx * co_loc, co_loc, axis=2)
        Zi = jax.lax.dynamic_slice_in_dim(Zi, idx * co_loc, co_loc, axis=2)
        sp4 = _local_spec(spec, x.shape[0], x.shape[1], co_loc)
        return stage_output_inverse(Zr, Zi, sp4, epilogue=plan.epilogue,
                                    bias=bias, residual=residual,
                                    spectrum=plan.spectrum)

    def _body_full(self, x, k, *ep_args, plan, spec, n_model):
        """k: (C'_full, C_loc, kh, kw) — stage 2 inline on the local slab."""
        sp2 = _local_spec(spec, x.shape[0], k.shape[1], k.shape[0])
        Gr, Gi = stage_kernel_transform(k, sp2, plan.spectrum)
        return self._body(x, Gr, Gi, *ep_args, plan=plan, spec=spec,
                          n_model=n_model)

    def prepare(self, plan, k):
        spec = padded_sharded_spec(plan)
        n_model = plan.mesh.shape[plan.model_axis]
        kp = _pad_axis(_pad_axis(k, 0, n_model), 1, n_model)
        return stage_kernel_transform(kp, spec, plan.spectrum)

    def _run(self, plan, x, args, body, extra_in_specs):
        mesh = plan.mesh
        xp = _pad_axis(_pad_axis(x, 0, mesh.shape[plan.data_axis]), 1,
                       mesh.shape[plan.model_axis])
        in_specs = (P(plan.data_axis, plan.model_axis, None, None),
                    *extra_in_specs)
        out_spec = P(plan.data_axis, plan.model_axis, None, None)
        y = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_spec)(xp, *args)
        return y[:plan.spec.B, :plan.spec.Cout].astype(x.dtype)

    def execute(self, plan, x, G, bias=None, residual=None):
        spec = padded_sharded_spec(plan)
        n_model = plan.mesh.shape[plan.model_axis]
        ep_args, ep_specs = _epilogue_operands(plan, bias, residual)
        body = functools.partial(self._body, plan=plan, spec=spec,
                                 n_model=n_model)
        g_spec = P(None, plan.model_axis, None)        # G: C sharded
        return self._run(plan, x, (*G, *ep_args), body,
                         (g_spec, g_spec, *ep_specs))

    def full(self, plan, x, k, bias=None, residual=None):
        spec = padded_sharded_spec(plan)
        n_model = plan.mesh.shape[plan.model_axis]
        kp = _pad_axis(_pad_axis(k, 0, n_model), 1, n_model)
        ep_args, ep_specs = _epilogue_operands(plan, bias, residual)
        body = functools.partial(self._body_full, plan=plan, spec=spec,
                                 n_model=n_model)
        k_spec = P(None, plan.model_axis, None, None)  # k: C sharded
        return self._run(plan, x, (kp, *ep_args), body, (k_spec, *ep_specs))


PIPELINES = {"local": LocalPipeline, "nfft": NfftPipeline,
             "wfft": WfftPipeline}


def pipeline_for(schedule: str, cgemm_fn=None, inverse_fn=None):
    return PIPELINES[schedule](cgemm_fn, inverse_fn)
