"""Plan/execute convolution engine (FFTW-style).

The best convolution algorithm is geometry-dependent (direct vs FFT
crossover; tile size; 3M vs 4M complex product; nFFT tuple partitioning vs
wFFT), so selection lives in a planner rather than at call sites:

    plan = plan_conv(x.shape, k.shape, padding=1)   # plan once
    y = plan(x, k)                                  # execute many times

``ConvPlan`` freezes everything the execution needs: the geometry
(``ConvSpec``), the (backend, schedule) pair, precision, and tuning
parameters (``three_m``, CGEMM block sizes, mesh axes).  Plans are
memoized in a keyed LRU cache so repeated layer shapes pay planning once.

On top of the one-shot ``plan(x, k)`` there is a prepare/execute split for
fixed kernels (inference / serving):

    prepared = plan.prepare(k, weights_version=step)   # stage 2 runs here
    y = prepared(x)                                    # stage 2 never again

``prepare`` caches the transformed kernel ``G`` in the exact layout the
schedule consumes — for ``nfft`` the post-all-to-all P-slab form, so
prepared sharded execution runs the kernel transform AND boundary
all-to-all #2 zero times.  The cache is keyed by ``weights_version``:
prepare with a new version recomputes (invalidation), with the same
version returns the cached ``PreparedConv``.

``backend="auto"`` picks direct vs FFT from the ``ConvSpec`` cost model;
``schedule="auto"`` picks ``nfft`` when a mesh is given, else ``local``.
``backend="tuned"`` replaces the cost model with *measured* selection
(``repro.conv.autotune``): candidate (backend, schedule, block) configs are
timed on the actual device, the winner is cached persistently per machine,
and the chosen blocks ride the plan down into the Pallas kernels.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
from typing import Any, Optional

from repro.core.conv_spec import ConvSpec
from repro.conv import registry
from repro.conv import autodiff
from repro.conv.epilogue import Epilogue


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Frozen, executable schedule for one convolution geometry.

    Execute with ``plan(x, k)``; ``x`` must be ``(B, C, H, W)`` and ``k``
    ``(C', C, kh, kw)`` matching the planned shapes exactly (plan again
    for a new geometry — planning is cached, so this is cheap).
    """
    spec: ConvSpec
    backend: str                       # resolved registry name
    schedule: str                      # resolved registry name
    padding: tuple                     # (pad_h, pad_w)
    three_m: bool = True               # 3M (Karatsuba) vs 4M complex product
    bm: Optional[int] = None           # Pallas CGEMM block sizes
    bn: Optional[int] = None
    bk: Optional[int] = None
    dft_bt: Optional[int] = None       # Pallas dft_tile tile-batch block
    compute_dtype: Any = None          # CGEMM operand dtype (e.g. bf16)
    mesh: Any = None                   # jax Mesh for sharded schedules
    data_axis: str = "data"
    model_axis: str = "model"
    replicate_kernel_transform: bool = False
    epilogue: Epilogue = Epilogue()    # fused elementwise tail (stage 4)
    spectrum: str = "real"             # "real" (compact Hermitian) | "complex"
    overlap: str = "off"               # "off" | "slab:<k>" sub-slab overlap

    @property
    def num_slabs(self) -> int:
        """Batch sub-slab count of the overlapped execution (1 = off)."""
        return _parse_overlap(self.overlap)

    # ---- execution --------------------------------------------------------
    def __call__(self, x, k, *, bias=None, residual=None):
        """Execute the plan.  Plans with a non-noop ``epilogue`` take the
        epilogue *operands* here: ``plan(x, k, bias=b, residual=r)`` —
        fused into stage 4 inside the pipeline (sharded schedules touch
        only their local 1/N output slab, zero extra collectives)."""
        self._check_x(x)
        if tuple(k.shape) != self.k_shape:
            raise ValueError(
                f"plan was built for kernel {self.k_shape}, got "
                f"{tuple(k.shape)}; call plan_conv for the new geometry")
        self._check_epilogue_operands(bias, residual)
        be = registry.get_backend(self.backend)
        if be.pipeline_factory is not None:
            return autodiff.pipeline_conv(self, x, k, bias, residual)
        if not self.epilogue.is_noop:
            return be.execute(self, x, k, bias=bias, residual=residual)
        return be.execute(self, x, k)

    def _check_x(self, x):
        if tuple(x.shape) != self.x_shape:
            raise ValueError(
                f"plan was built for input {self.x_shape}, got "
                f"{tuple(x.shape)}; call plan_conv for the new geometry")

    def _check_epilogue_operands(self, bias, residual):
        ep = self.epilogue
        if ep.bias != (bias is not None):
            raise ValueError(
                f"plan epilogue declares bias={ep.bias} but bias "
                f"{'was not' if ep.bias else 'was'} passed at execution")
        if ep.residual != (residual is not None):
            raise ValueError(
                f"plan epilogue declares residual={ep.residual} but "
                f"residual {'was not' if ep.residual else 'was'} passed "
                "at execution")
        if bias is not None and tuple(bias.shape) != (self.spec.Cout,):
            raise ValueError(
                f"epilogue bias must have shape ({self.spec.Cout},), got "
                f"{tuple(bias.shape)}")
        if residual is not None and tuple(residual.shape) != self.out_shape:
            raise ValueError(
                f"epilogue residual must match the output {self.out_shape},"
                f" got {tuple(residual.shape)}")

    # ---- prepare/execute split --------------------------------------------
    def prepare(self, k, *, weights_version=None) -> "PreparedConv":
        """Run the kernel transform (stage 2) once; return a ``PreparedConv``
        executing the remaining stages against the cached result.

        The prepared cache is keyed by (plan, kernel object): each layer's
        kernel gets its own entry even when same-geometry layers share a
        plan.  ``weights_version`` is the staleness check — preparing the
        same kernel under the same version returns the memoized
        ``PreparedConv`` without re-transforming; a different version
        recomputes and replaces it (weight update -> invalidation).
        ``None`` always recomputes and is never cached.  Call outside
        ``jit`` — the transform runs eagerly here so execution never
        re-traces it.
        """
        if tuple(k.shape) != self.k_shape:
            raise ValueError(
                f"plan was built for kernel {self.k_shape}, got "
                f"{tuple(k.shape)}; call plan_conv for the new geometry")
        import jax
        if isinstance(k, jax.core.Tracer):
            raise ValueError(
                "plan.prepare must run outside jit/grad (it caches the "
                "concrete transformed kernel); prepare eagerly and close "
                "over the PreparedConv, or use plan(x, k) when k is traced")
        global _prepared_hits, _prepared_misses, _prepared_invalidations
        # Key by (plan, kernel object): same-geometry layers share one
        # ConvPlan, so the plan alone would hand layer B layer A's cached
        # transform.  The PreparedConv pins k, so id(k) is unambiguous for
        # as long as its entry lives.
        cache_key = (self, id(k))
        if weights_version is not None:
            with _prepared_lock:
                slot = _prepared_cache.get(cache_key)
                if slot is not None and slot[0] == weights_version:
                    _prepared_hits += 1
                    _prepared_cache.move_to_end(cache_key)
                    return slot[1]
        be = registry.get_backend(self.backend)
        if be.pipeline_factory is not None:
            state = be.make_pipeline(self).prepare(self, k)
        else:
            state = k              # opaque backend: nothing to pre-transform
        prepared = PreparedConv(plan=self, state=state, kernel=k,
                                weights_version=weights_version)
        if weights_version is not None:
            with _prepared_lock:
                if cache_key in _prepared_cache:
                    _prepared_invalidations += 1
                    _prepared_cache.move_to_end(cache_key)
                _prepared_misses += 1
                _prepared_cache[cache_key] = (weights_version, prepared)
                # same LRU bound as the plan cache: prepared G pytrees are
                # the big arrays, don't let them accumulate unboundedly
                cap = plan_cache_capacity()
                while len(_prepared_cache) > cap:
                    _prepared_cache.popitem(last=False)
        return prepared

    # ---- introspection ----------------------------------------------------
    def analyze(self, *, prepared: bool = False):
        """Static analysis of this plan's traced program: collective
        counts, dtype flow, fusion/elision facts, peak live bytes — see
        ``repro.conv.analyze``.  ``analyze(prepared=True)`` profiles the
        prepared-execute path (kernel layout derived abstractly; no
        transform FLOPs run).  Certify with ``plan.analyze().check()``."""
        from repro.conv.analyze import analyze
        return analyze(self, prepared=prepared)

    @property
    def x_shape(self) -> tuple:
        s = self.spec
        return (s.B, s.C, s.H, s.W)

    @property
    def k_shape(self) -> tuple:
        s = self.spec
        return (s.Cout, s.C, s.kh, s.kw)

    @property
    def out_shape(self) -> tuple:
        s = self.spec
        return (s.B, s.Cout, s.Ho, s.Wo)

    @property
    def differentiable(self) -> bool:
        be = registry.get_backend(self.backend)
        return self.schedule in be.differentiable

    def flops(self) -> int:
        """Cost-model FLOPs of the planned path (for rooflines)."""
        if self.backend == "direct":
            return self.spec.direct_flops()
        return self.spec.cgemm_flops(three_m=self.three_m,
                                     spectrum=self.spectrum) \
            + self.spec.transform_flops()

    def describe(self) -> str:
        s = self.spec
        lines = [
            f"ConvPlan {self.x_shape} * {self.k_shape} -> {self.out_shape}",
            f"  backend={self.backend} schedule={self.schedule} "
            f"three_m={self.three_m} delta={s.delta} "
            f"spectrum={self.spectrum} epilogue={self.epilogue.describe()}",
            f"  cost-model FLOPs: direct {s.direct_flops():.3e}, fft "
            f"{s.cgemm_flops(three_m=self.three_m) + s.transform_flops():.3e}",
        ]
        if self.mesh is not None:
            n_data = self.mesh.shape[self.data_axis]
            n_model = self.mesh.shape[self.model_axis]
            lines.append(
                f"  mesh axes: {self.data_axis}={n_data} "
                f"x {self.model_axis}={n_model}, replicate_kernel_transform="
                f"{self.replicate_kernel_transform}, overlap={self.overlap}")
        if self.bm or self.bn or self.bk or self.dft_bt:
            lines.append(f"  blocks bm={self.bm} bn={self.bn} bk={self.bk} "
                         f"dft_bt={self.dft_bt}")
        if self.compute_dtype is not None:
            lines.append(f"  compute_dtype={self.compute_dtype}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash: jit-able
class PreparedConv:
    """A plan bound to a prepared (already-transformed) kernel.

    ``prepared(x)`` runs stages 1/3/4 (+ the schedule's remaining
    collectives); stage 2 and — for ``nfft`` — boundary all-to-all #2 were
    paid once in ``plan.prepare``.  Pipeline backends are differentiable
    w.r.t. ``x`` (the plan-level VJP, so ``fft-pallas`` included); the
    kernel is frozen — to train it, use ``plan(x, k)``.
    """
    plan: ConvPlan
    state: Any                          # pipeline G pytree, or raw k (opaque)
    kernel: Any = None                  # original k (for the x-grad VJP)
    weights_version: Any = None

    def __call__(self, x, *, bias=None, residual=None):
        self.plan._check_x(x)
        self.plan._check_epilogue_operands(bias, residual)
        be = registry.get_backend(self.plan.backend)
        if be.pipeline_factory is not None:
            return autodiff.prepared_conv(self, x, bias, residual)
        if not self.plan.epilogue.is_noop:
            return be.execute(self.plan, x, self.state, bias=bias,
                              residual=residual)
        return be.execute(self.plan, x, self.state)

    @property
    def out_shape(self) -> tuple:
        return self.plan.out_shape

    def analyze(self):
        """Static analysis of the prepared execution path (stage 2 and —
        for nfft — boundary all-to-all #2 must be absent from the traced
        program); see ``repro.conv.analyze``."""
        from repro.conv.analyze import analyze
        return analyze(self)


# --------------------------------------------------------------------------
# Plan cache (bounded LRU) + prepared-kernel cache
# --------------------------------------------------------------------------

PlanCacheInfo = collections.namedtuple("PlanCacheInfo",
                                       ["hits", "misses", "size"])
PreparedCacheInfo = collections.namedtuple(
    "PreparedCacheInfo", ["hits", "misses", "invalidations", "size"])

_DEFAULT_CACHE_SIZE = 256

_cache_lock = threading.Lock()
_plan_cache: "collections.OrderedDict" = collections.OrderedDict()
_cache_hits = 0
_cache_misses = 0

_prepared_lock = threading.Lock()
# plan -> (weights_version, prepared); LRU-bounded like the plan cache
_prepared_cache: "collections.OrderedDict" = collections.OrderedDict()
_prepared_hits = 0
_prepared_misses = 0
_prepared_invalidations = 0


def plan_cache_capacity() -> int:
    """Max cached plans (env ``REPRO_CONV_PLAN_CACHE_SIZE``, default 256)."""
    try:
        cap = int(os.environ.get("REPRO_CONV_PLAN_CACHE_SIZE",
                                 _DEFAULT_CACHE_SIZE))
    except ValueError:
        cap = _DEFAULT_CACHE_SIZE
    return max(1, cap)


def plan_cache_info() -> PlanCacheInfo:
    with _cache_lock:
        return PlanCacheInfo(_cache_hits, _cache_misses, len(_plan_cache))


def clear_plan_cache() -> None:
    global _cache_hits, _cache_misses
    with _cache_lock:
        _plan_cache.clear()
        _cache_hits = 0
        _cache_misses = 0


def prepared_cache_info() -> PreparedCacheInfo:
    with _prepared_lock:
        return PreparedCacheInfo(_prepared_hits, _prepared_misses,
                                 _prepared_invalidations,
                                 len(_prepared_cache))


def clear_prepared_cache() -> None:
    global _prepared_hits, _prepared_misses, _prepared_invalidations
    with _prepared_lock:
        _prepared_cache.clear()
        _prepared_hits = 0
        _prepared_misses = 0
        _prepared_invalidations = 0


def _mesh_cache_key(mesh):
    """Value key for a mesh: two distinct Mesh objects over the same devices
    and axes share plan-cache entries (object identity would duplicate)."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


# --------------------------------------------------------------------------
# Planner
# --------------------------------------------------------------------------

def _normalize_padding(padding) -> tuple:
    if isinstance(padding, int):
        return (padding, padding)
    ph, pw = padding
    return (int(ph), int(pw))


def _build_spec(x_shape, k_shape, padding, delta) -> ConvSpec:
    """Validated ``ConvSpec`` for a conv geometry (shared with the
    autotuner so cache signatures can never drift from planner
    semantics).  Kernels larger than the tile get a widened (then-unused)
    tile so the spec validates; only ``direct`` can execute them."""
    B, C, H, W = x_shape
    Cout, C2, kh, kw = k_shape
    if C != C2:
        raise ValueError(f"channel mismatch: input C={C}, kernel C={C2}")
    return ConvSpec(B=B, C=C, Cout=Cout, H=H, W=W, kh=kh, kw=kw,
                    pad_h=padding[0], pad_w=padding[1],
                    delta=max(delta, kh, kw))


def _auto_backend(spec: ConvSpec, three_m: bool) -> str:
    """Direct-vs-FFT crossover on the ConvSpec cost model."""
    fft = spec.cgemm_flops(three_m=three_m) + spec.transform_flops()
    return "direct" if spec.direct_flops() <= fft else "fft-xla"


# overlap="auto" picks "off" below this per-rank batch: slabbing a tiny
# batch leaves each slab too small to amortize its collective's latency
# (and k=2 on b_loc<4 would pipeline 1-row slabs).
_AUTO_OVERLAP_MIN_B = 4


def _parse_overlap(overlap) -> int:
    """Sub-slab count encoded by a (resolved) overlap knob value:
    ``"off"`` -> 1, ``"slab:<k>"`` -> k (k >= 2).  ``"auto"`` must be
    resolved by the planner before it reaches here."""
    if overlap == "off":
        return 1
    if isinstance(overlap, str) and overlap.startswith("slab:"):
        try:
            k = int(overlap[len("slab:"):])
        except ValueError:
            k = 0
        if k >= 2:
            return k
    raise ValueError(
        f"unknown overlap {overlap!r} (choose 'off', 'slab:<k>' with "
        "k >= 2, or 'auto')")


def _resolve_overlap(overlap, spec, sched, be, backend, schedule, mesh,
                     data_axis) -> str:
    """Validate + normalize the overlap knob against the resolved
    (backend, schedule, mesh): ``"auto"`` picks ``"slab:2"`` on sharded
    pipelines with enough per-rank batch (else ``"off"``), and explicit
    slab counts are clamped once to the per-rank batch so every slab is
    non-empty (``"slab:1"`` never exists — it normalizes to ``"off"``)."""
    sharded_pipeline = sched.requires_mesh and be.pipeline_factory is not None
    b_loc = 0
    if sharded_pipeline:
        n_data = mesh.shape[data_axis]
        b_loc = (spec.B + (-spec.B) % n_data) // n_data
    if overlap == "auto":
        return "slab:2" if sharded_pipeline \
            and b_loc >= _AUTO_OVERLAP_MIN_B else "off"
    num_slabs = _parse_overlap(overlap)
    if num_slabs == 1:
        return "off"
    if not sharded_pipeline:
        raise ValueError(
            f"overlap={overlap!r} requires a sharded stage-pipeline "
            f"schedule (backend {backend!r} / schedule {schedule!r} has "
            "no boundary collectives to overlap); use overlap='off'")
    num_slabs = min(num_slabs, b_loc)
    return f"slab:{num_slabs}" if num_slabs > 1 else "off"


def _resolve(x_shape, k_shape, padding, delta, backend, schedule, mesh,
             three_m, bm, bn, bk, dft_bt, compute_dtype, data_axis,
             model_axis, replicate_kernel_transform, epilogue,
             spectrum, overlap="off") -> ConvPlan:
    _, _, kh, kw = k_shape
    if spectrum == "auto":
        spectrum = "real"    # compact Hermitian layout is the default path
    if spectrum not in ("real", "complex"):
        raise ValueError(
            f"unknown spectrum {spectrum!r} (choose 'real', 'complex', or "
            "'auto')")
    # Kernels larger than the FFT tile rule out the FFT backends but are
    # fine for direct conv: _build_spec widens the (then-unused) tile so
    # the spec validates, and auto resolves to direct below.
    oversize = max(kh, kw) > delta
    if oversize and backend not in ("auto", "direct"):
        registry.get_backend(backend)        # unknown names error first
        raise ValueError(
            f"kernel {kh}x{kw} exceeds tile size delta={delta}; only the "
            f"'direct' backend supports it (requested {backend!r})")
    spec = _build_spec(x_shape, k_shape, padding, delta)

    # -- schedule -----------------------------------------------------------
    if schedule == "auto":
        schedule = "nfft" if mesh is not None else "local"
    sched = registry.get_schedule(schedule)
    if sched.requires_mesh and mesh is None:
        raise ValueError(f"schedule {schedule!r} requires a mesh")
    if not sched.requires_mesh and mesh is not None:
        raise ValueError(
            f"schedule {schedule!r} ignores the mesh; pass schedule='nfft' "
            "or 'wfft' (or drop the mesh)")
    if sched.requires_mesh:
        for axis in (data_axis, model_axis):
            if axis not in mesh.shape:
                raise ValueError(
                    f"mesh has no axis {axis!r} (axes: {tuple(mesh.shape)})")
        # Channel axes are zero-padded up to model-axis multiples inside
        # the pipelines, and the frequency (P) axis is padded once before
        # the nfft boundary all-to-alls — no divisibility precondition.

    # -- backend ------------------------------------------------------------
    if backend == "auto":
        if oversize:
            backend = "direct"
        else:
            backend = "fft-xla" if sched.requires_mesh \
                else _auto_backend(spec, three_m)
    be = registry.get_backend(backend)
    if schedule not in be.schedules:
        raise ValueError(
            f"backend {backend!r} does not support schedule {schedule!r} "
            f"(supported: {be.schedules})")
    if not epilogue.is_noop and not be.epilogue_capable:
        raise ValueError(
            f"backend {backend!r} cannot fuse an epilogue "
            f"({epilogue.describe()}); register it with "
            "supports_epilogue=True or use a stage-pipeline backend")
    if spectrum == "complex" and be.pipeline_factory is None:
        raise ValueError(
            f"spectrum='complex' (the full-spectrum twin) only applies to "
            f"the FFT stage pipelines; backend {backend!r} has no spectrum")

    # -- overlap (comm/compute-overlapped sub-slab execution) ---------------
    overlap = _resolve_overlap(overlap, spec, sched, be, backend, schedule,
                               mesh, data_axis)
    num_slabs = _parse_overlap(overlap)
    if num_slabs > 1 and backend == "fft-pallas":
        # Pin the Pallas CGEMM blocks ONCE against the smallest sub-slab's
        # geometry so every slab shares one block config — per-slab
        # resolution would re-pad the small slabs on every call (certified
        # by the analyzer's overlap-uniform-blocks invariant).  Explicit
        # caller pins pass through resolve_blocks verbatim.
        from repro.kernels.cgemm.ops import resolve_blocks
        n_data = mesh.shape[data_axis]
        n_model = mesh.shape[model_axis]
        b_loc = (spec.B + (-spec.B) % n_data) // n_data
        c_pad = spec.C + (-spec.C) % n_model
        co_pad = spec.Cout + (-spec.Cout) % n_model
        m_min = (b_loc // num_slabs) * spec.n_tiles
        k_dim = c_pad if schedule == "nfft" else max(1, c_pad // n_model)
        bm, bn, bk = resolve_blocks(m_min, co_pad, k_dim, bm, bn, bk)

    return ConvPlan(spec=spec, backend=backend, schedule=schedule,
                    padding=padding, three_m=three_m, bm=bm, bn=bn, bk=bk,
                    dft_bt=dft_bt, compute_dtype=compute_dtype, mesh=mesh,
                    data_axis=data_axis, model_axis=model_axis,
                    replicate_kernel_transform=replicate_kernel_transform,
                    epilogue=epilogue, spectrum=spectrum, overlap=overlap)


def plan_conv(spec, k_shape=None, *, padding=None, delta: Optional[int] = None,
              backend: str = "auto", schedule: str = "auto", mesh=None,
              three_m: bool = True, bm=None, bn=None, bk=None, dft_bt=None,
              compute_dtype=None, data_axis: str = "data",
              model_axis: str = "model",
              replicate_kernel_transform: bool = False,
              epilogue: Optional[Epilogue] = None,
              spectrum: str = "auto",
              overlap: str = "off",
              cache: bool = True) -> ConvPlan:
    """Create (or fetch from the plan cache) a ``ConvPlan``.

    Args:
      spec: a ``ConvSpec`` (geometry + padding + delta in one object —
        the same spec ``autotune.tune`` accepts), or the input shape
        ``(B, C, H, W)`` with ``k_shape``/``padding``/``delta`` given
        separately.
      k_shape: kernel shape ``(C', C, kh, kw)`` with ``kh, kw <= delta``
        (shape-tuple form only — a ``ConvSpec`` already carries it).
      padding: int or ``(ph, pw)`` zero padding (default 0).
      delta: FFT tile size (the paper uses 16).
      backend: ``"direct"`` | ``"fft-xla"`` | ``"fft-pallas"`` | ``"auto"``
        (cost-model crossover; never auto-selects Pallas) | ``"tuned"``
        (measured on-device selection via ``repro.conv.autotune`` — warm
        persistent cache, cost-model fallback when measurement is
        disabled; the tuner also picks schedule and blocks unless pinned
        here).
      schedule: ``"local"`` | ``"nfft"`` | ``"wfft"`` | ``"auto"``
        (``nfft`` when a mesh is given, else ``local``; with
        ``backend="tuned"`` the tuner measures nfft vs wfft).
      mesh: jax Mesh with ``data_axis``/``model_axis``; required by the
        sharded schedules.  Cached plans key meshes by value
        ``(axis_names, shape, device ids)``, so equal meshes share entries.
      three_m: 3-matmul (Karatsuba) vs 4-matmul complex product.
      bm, bn, bk: Pallas CGEMM block sizes (``fft-pallas`` only).
      dft_bt: Pallas ``dft_tile`` tile-batch block (``fft-pallas`` fused
        inverse tail only).
      compute_dtype: CGEMM operand dtype (e.g. bf16; f32 accumulation).
        On the sharded schedules the cast happens before the hot-path
        collective (nfft boundary a2a / wfft in-stage psum), halving its
        bytes.
      replicate_kernel_transform: nfft only — replicate the cheap kernel
        transform on every model rank instead of all-to-all-ing it.
      epilogue: ``Epilogue`` fused into stage 4 (bias add, activation,
        residual add) on the local output slab, before the output dtype
        cast — zero extra collectives, zero extra stage ops.  The operand
        values are execution arguments: ``plan(x, k, bias=b, residual=r)``.
      spectrum: frequency-domain layout for the FFT pipelines.  ``"real"``
        (the ``"auto"`` default) flows the compact Hermitian half-spectrum
        (~0.51x the frequency points at delta=16) through every stage —
        the nfft all-to-alls and wfft psum move roughly half the bytes;
        ``"complex"`` is the full-spectrum twin (measurement baseline).
        With ``backend="tuned"`` and ``spectrum="auto"`` the tuner picks
        per geometry.
      overlap: comm/compute-overlapped execution for the sharded
        schedules.  ``"slab:<k>"`` splits the per-rank batch into k
        sub-slabs inside the shard_map body and double-buffers, so the
        boundary collective of slab i+1 overlaps the hot cgemm of slab i
        (requires the async-collective / latency-hiding XLA flags —
        ``repro.launch.env``).  ``"auto"`` picks ``"slab:2"`` on sharded
        pipelines with per-rank batch >= 4, else ``"off"``; slab counts
        are clamped to the per-rank batch.  ``"off"`` (default) is the
        sequential path.  With ``backend="tuned"`` and ``overlap="auto"``
        the tuner measures the overlap axis.
      cache: memoize the plan under its argument key (bounded LRU, see
        ``plan_cache_capacity``).

    Returns:
      A frozen ``ConvPlan``; call it as ``plan(x, k)`` or split with
      ``plan.prepare(k)``.
    """
    global _cache_hits, _cache_misses
    if isinstance(spec, ConvSpec):
        if k_shape is not None or padding is not None or delta is not None:
            raise TypeError(
                "plan_conv(spec, ...): a ConvSpec already carries k_shape/"
                "padding/delta — pass them only with the shape-tuple form")
        x_shape = (spec.B, spec.C, spec.H, spec.W)
        k_shape = (spec.Cout, spec.C, spec.kh, spec.kw)
        padding = (spec.pad_h, spec.pad_w)
        delta = spec.delta
    else:
        if k_shape is None:
            raise TypeError(
                "plan_conv(x_shape, k_shape, ...): k_shape is required "
                "with the shape-tuple form (or pass a ConvSpec)")
        x_shape = spec
        padding = 0 if padding is None else padding
        delta = 16 if delta is None else delta
    x_shape, k_shape = tuple(map(int, x_shape)), tuple(map(int, k_shape))
    padding = _normalize_padding(padding)
    epilogue = Epilogue() if epilogue is None else epilogue
    if backend == "tuned":
        # Measured selection resolves BEFORE the plan cache, so the plan
        # is memoized under the *resolved* config: a cost-model fallback
        # (measurement disabled / cold-and-offline) is never frozen in —
        # once the tuning cache warms, the next call adopts the winner.
        if max(k_shape[2], k_shape[3]) > delta:
            backend = "direct"      # oversize kernel: only direct fits
        else:
            from repro.conv import autotune
            # tune unpinned: pins constrain the *plan*, not the machine's
            # measured winner (pinned tune() calls get their own cache key)
            tuned = autotune.tune(
                x_shape, k_shape, padding=padding, delta=delta,
                schedule=schedule, mesh=mesh, three_m=three_m,
                compute_dtype=compute_dtype, data_axis=data_axis,
                model_axis=model_axis,
                replicate_kernel_transform=replicate_kernel_transform,
                spectrum=spectrum, overlap=overlap)
            backend = tuned.backend
            if schedule == "auto":
                schedule = tuned.schedule
            if spectrum == "auto":
                spectrum = tuned.spectrum
            if overlap == "auto":
                overlap = tuned.overlap
            # explicit caller overrides beat tuned blocks
            bm = bm if bm is not None else tuned.bm
            bn = bn if bn is not None else tuned.bn
            bk = bk if bk is not None else tuned.bk
            dft_bt = dft_bt if dft_bt is not None else tuned.dft_bt
    if spectrum == "auto":
        spectrum = "real"    # deterministic default — share the cache entry
    key = (x_shape, k_shape, padding, delta, backend, schedule,
           _mesh_cache_key(mesh), three_m, bm, bn, bk, dft_bt,
           compute_dtype, data_axis, model_axis,
           replicate_kernel_transform, epilogue, spectrum, overlap)
    if cache:
        with _cache_lock:
            plan = _plan_cache.get(key)
            if plan is not None:
                _cache_hits += 1
                _plan_cache.move_to_end(key)
                return plan
    plan = _resolve(x_shape, k_shape, padding, delta, backend, schedule,
                    mesh, three_m, bm, bn, bk, dft_bt, compute_dtype,
                    data_axis, model_axis, replicate_kernel_transform,
                    epilogue, spectrum, overlap)
    if cache:
        with _cache_lock:
            _cache_misses += 1
            _plan_cache[key] = plan
            _plan_cache.move_to_end(key)
            cap = plan_cache_capacity()
            while len(_plan_cache) > cap:
                _plan_cache.popitem(last=False)
    return plan


def conv2d(x, k, **kwargs):
    """One-shot convenience: ``plan_conv(x.shape, k.shape, **kwargs)(x, k)``.

    The plan cache makes repeated same-shape calls pay planning once.
    """
    return plan_conv(tuple(x.shape), tuple(k.shape), **kwargs)(x, k)
