"""Built-in backends/schedules for the plan-execute convolution engine.

Schedules:
  local  single device, no collectives.
  nfft   the paper's NUMA-aware tuple partitioning: transforms run where
         the data lives, one all_to_all per stage boundary, collective-free
         hot CGEMM.
  wfft   the Wang et al. baseline: channel-sharded CGEMM with an
         all-reduce inside the hot stage.

Backends:
  direct      lax.conv_general_dilated (the oracle path; wins for small
              channel counts / tiny kernels by the cost model).  Opaque
              execute, native XLA autodiff; the plan epilogue is applied
              right after the conv (XLA fuses the elementwise tail).
  fft-xla     the paper's 4-stage pipeline composed from repro.conv.stages
              with the XLA einsum CGEMM.
  fft-pallas  the same stage graph with the hot CGEMM swapped for the
              Pallas TPU kernel (interpret mode on CPU); plan bm/bn/bk
              select its blocks.  On the ``local`` schedule a bias/
              activation epilogue is fused into the ``dft_tile``
              output-inverse kernel tail (the inverse never round-trips to
              HBM before the elementwise pass).

The two FFT backends differ *only* in the stage ops they inject into the
pipeline — everything else (transforms, collectives, prepare/execute, the
plan-level VJP, epilogue fusion) is shared composition, which is why both
are differentiable on every schedule.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.conv import stages
from repro.conv.epilogue import apply_epilogue
from repro.conv.registry import register_backend, register_schedule
from repro.core import fftconv as F


def _pallas_cgemm_fn(plan):
    from repro.kernels.cgemm import cgemm_pallas
    return functools.partial(cgemm_pallas, three_m=plan.three_m,
                             bm=plan.bm, bn=plan.bn, bk=plan.bk)


def _pallas_fused_inverse(Zr, Zi, spec, epilogue, bias, *, bt=None):
    """Stage 4 through the fused dft_tile kernel: inverse DFT + bias +
    activation in one VMEM-resident tail.

    The activation runs on whole tiles before the overlap-save crop; the
    crop only *selects* elements, so elementwise-before-crop equals
    crop-then-elementwise on everything kept.  ``bt`` is the plan's
    ``dft_bt`` tile-batch block override (autotuned or user-pinned).
    """
    from repro.kernels.dft_tile import tile_ifft_epilogue_pallas
    Zrt = F.z_to_tiles(Zr, spec)            # (B, C', X, Dl, d, dh)
    Zit = F.z_to_tiles(Zi, spec)
    B, Co, X, Dl = Zrt.shape[:4]
    n = B * Co * X * Dl
    d, dh = spec.delta, spec.delta_h
    b = bias if bias is not None else jnp.zeros((Co,), Zr.dtype)
    # one bias scalar per tile: broadcast over (B, ., X, Dl) tile indices
    b_tile = jnp.broadcast_to(b.astype(Zr.dtype)[None, :, None, None],
                              (B, Co, X, Dl)).reshape(n)
    y = tile_ifft_epilogue_pallas(Zrt.reshape(n, d, dh),
                                  Zit.reshape(n, d, dh), b_tile,
                                  activation=epilogue.activation,
                                  delta=d, bt=bt)
    return F.assemble_output_tiles(y.reshape(B, Co, X, Dl, d, d), spec)


def _pallas_fused_inverse_real(Zr, Zi, spec, epilogue, bias, *, bt=None):
    """The ``spectrum="real"`` fused stage-4 tail: compact-layout scatter +
    inverse DFT + bias + activation in one ``dft_tile`` kernel pass."""
    from repro.kernels.dft_tile import tile_irfft_epilogue_pallas
    from repro.core.dft import num_freq_real
    P = num_freq_real(spec.delta)
    Zrt = F.z_to_flat_tiles(Zr, spec, P)    # (B, C', X, Dl, P)
    Zit = F.z_to_flat_tiles(Zi, spec, P)
    B, Co, X, Dl = Zrt.shape[:4]
    n = B * Co * X * Dl
    d = spec.delta
    b = bias if bias is not None else jnp.zeros((Co,), Zr.dtype)
    b_tile = jnp.broadcast_to(b.astype(Zr.dtype)[None, :, None, None],
                              (B, Co, X, Dl)).reshape(n)
    y = tile_irfft_epilogue_pallas(Zrt.reshape(n, P), Zit.reshape(n, P),
                                   b_tile, activation=epilogue.activation,
                                   delta=d, bt=bt)
    return F.assemble_output_tiles(y.reshape(B, Co, X, Dl, d, d), spec)


def _exec_direct(plan, x, k, bias=None, residual=None):
    y = F.conv2d_direct(x, k, padding=plan.padding,
                        compute_dtype=plan.compute_dtype)
    out_dtype = y.dtype
    return apply_epilogue(y, plan.epilogue, bias=bias,
                          residual=residual).astype(out_dtype)


def _fft_xla_pipeline(plan):
    return stages.pipeline_for(plan.schedule, cgemm_fn=None)


def _fft_pallas_pipeline(plan):
    inverse_fn = None
    if plan.schedule == "local" and plan.spectrum == "real":
        # fused dft_tile tail for the compact layout; the full-spectrum
        # twin takes the composed stage-4 path (it is the measurement
        # baseline, not the fast path)
        inverse_fn = functools.partial(_pallas_fused_inverse_real,
                                       bt=plan.dft_bt)
    return stages.pipeline_for(plan.schedule,
                               cgemm_fn=_pallas_cgemm_fn(plan),
                               inverse_fn=inverse_fn)


def register_builtin() -> None:
    register_schedule("local", requires_mesh=False,
                      description="single device, no collectives")
    register_schedule("nfft", requires_mesh=True,
                      description="paper: tuple partitioning, a2a at stage "
                                  "boundaries, collective-free CGEMM")
    register_schedule("wfft", requires_mesh=True,
                      description="baseline: all-reduce inside the hot CGEMM")

    register_backend("direct", _exec_direct, schedules=("local",),
                     native_autodiff=True, supports_epilogue=True,
                     description="lax.conv_general_dilated")
    register_backend("fft-xla", pipeline_factory=_fft_xla_pipeline,
                     schedules=("local", "nfft", "wfft"),
                     description="FFT conv stage graph, XLA einsum CGEMM")
    register_backend("fft-pallas", pipeline_factory=_fft_pallas_pipeline,
                     schedules=("local", "nfft", "wfft"),
                     description="FFT conv stage graph, Pallas CGEMM kernel"
                                 " (+ fused epilogue inverse on local)")
