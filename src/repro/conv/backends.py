"""Built-in backends/schedules for the plan-execute convolution engine.

Schedules:
  local  single device, no collectives.
  nfft   the paper's NUMA-aware tuple partitioning: transforms run where
         the data lives, one all_to_all per stage boundary, collective-free
         hot CGEMM (repro.parallel.fftconv_dist).
  wfft   the Wang et al. baseline: channel-sharded CGEMM with an
         all-reduce inside the hot stage.

Backends:
  direct      lax.conv_general_dilated (the oracle path; wins for small
              channel counts / tiny kernels by the cost model).
  fft-xla     the paper's 4-stage FFT convolution with the XLA einsum
              CGEMM; differentiable (custom VJP) on the local schedule.
  fft-pallas  same pipeline with the hot CGEMM in the Pallas TPU kernel
              (interpret mode on CPU); plan bm/bn/bk select its blocks.
"""
from __future__ import annotations

import functools

from repro.conv.registry import register_backend, register_schedule
from repro.core import fftconv as F
from repro.core.cgemm import cgemm


def _pallas_cgemm_fn(plan):
    from repro.kernels.cgemm import cgemm_pallas
    return functools.partial(cgemm_pallas, three_m=plan.three_m,
                             bm=plan.bm, bn=plan.bn, bk=plan.bk)


def _exec_direct(plan, x, k):
    return F.conv2d_direct(x, k, padding=plan.padding)


def _exec_fft(plan, x, k, cgemm_fn=None):
    if plan.schedule == "local":
        if cgemm_fn is None:
            # custom-VJP path: differentiable, FFT-conv fwd + bwd
            return F._fft_conv2d(x, k, plan.padding, plan.spec.delta,
                                 plan.three_m)
        return F._fft_conv2d_impl(x, k, plan.spec, plan.three_m,
                                  cgemm_fn=cgemm_fn)
    from repro.parallel.fftconv_dist import _fft_conv2d_sharded_impl
    return _fft_conv2d_sharded_impl(
        x, k, plan.mesh, strategy=plan.schedule, padding=plan.padding,
        delta=plan.spec.delta, three_m=plan.three_m,
        data_axis=plan.data_axis, model_axis=plan.model_axis,
        cgemm_fn=cgemm_fn,
        replicate_kernel_transform=plan.replicate_kernel_transform,
        compute_dtype=plan.compute_dtype)


def _exec_fft_xla(plan, x, k):
    return _exec_fft(plan, x, k, cgemm_fn=None)


def _exec_fft_pallas(plan, x, k):
    return _exec_fft(plan, x, k, cgemm_fn=_pallas_cgemm_fn(plan))


def register_builtin() -> None:
    register_schedule("local", requires_mesh=False,
                      description="single device, no collectives")
    register_schedule("nfft", requires_mesh=True,
                      description="paper: tuple partitioning, a2a at stage "
                                  "boundaries, collective-free CGEMM")
    register_schedule("wfft", requires_mesh=True,
                      description="baseline: all-reduce inside the hot CGEMM")

    register_backend("direct", _exec_direct, schedules=("local",),
                     differentiable=("local",),
                     description="lax.conv_general_dilated")
    register_backend("fft-xla", _exec_fft_xla,
                     schedules=("local", "nfft", "wfft"),
                     differentiable=("local",),
                     description="FFT conv, XLA einsum CGEMM")
    register_backend("fft-pallas", _exec_fft_pallas,
                     schedules=("local", "nfft", "wfft"),
                     description="FFT conv, Pallas CGEMM kernel")
