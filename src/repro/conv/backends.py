"""Built-in backends/schedules for the plan-execute convolution engine.

Schedules:
  local  single device, no collectives.
  nfft   the paper's NUMA-aware tuple partitioning: transforms run where
         the data lives, one all_to_all per stage boundary, collective-free
         hot CGEMM.
  wfft   the Wang et al. baseline: channel-sharded CGEMM with an
         all-reduce inside the hot stage.

Backends:
  direct      lax.conv_general_dilated (the oracle path; wins for small
              channel counts / tiny kernels by the cost model).  Opaque
              execute, native XLA autodiff.
  fft-xla     the paper's 4-stage pipeline composed from repro.conv.stages
              with the XLA einsum CGEMM.
  fft-pallas  the same stage graph with the hot CGEMM swapped for the
              Pallas TPU kernel (interpret mode on CPU); plan bm/bn/bk
              select its blocks.

The two FFT backends differ *only* in the CGEMM stage op they inject into
the pipeline — everything else (transforms, collectives, prepare/execute,
the plan-level VJP) is shared composition, which is why both are
differentiable on every schedule.
"""
from __future__ import annotations

import functools

from repro.conv import stages
from repro.conv.registry import register_backend, register_schedule
from repro.core import fftconv as F


def _pallas_cgemm_fn(plan):
    from repro.kernels.cgemm import cgemm_pallas
    return functools.partial(cgemm_pallas, three_m=plan.three_m,
                             bm=plan.bm, bn=plan.bn, bk=plan.bk)


def _exec_direct(plan, x, k):
    return F.conv2d_direct(x, k, padding=plan.padding,
                           compute_dtype=plan.compute_dtype)


def _fft_xla_pipeline(plan):
    return stages.pipeline_for(plan.schedule, cgemm_fn=None)


def _fft_pallas_pipeline(plan):
    return stages.pipeline_for(plan.schedule, cgemm_fn=_pallas_cgemm_fn(plan))


def register_builtin() -> None:
    register_schedule("local", requires_mesh=False,
                      description="single device, no collectives")
    register_schedule("nfft", requires_mesh=True,
                      description="paper: tuple partitioning, a2a at stage "
                                  "boundaries, collective-free CGEMM")
    register_schedule("wfft", requires_mesh=True,
                      description="baseline: all-reduce inside the hot CGEMM")

    register_backend("direct", _exec_direct, schedules=("local",),
                     native_autodiff=True,
                     description="lax.conv_general_dilated")
    register_backend("fft-xla", pipeline_factory=_fft_xla_pipeline,
                     schedules=("local", "nfft", "wfft"),
                     description="FFT conv stage graph, XLA einsum CGEMM")
    register_backend("fft-pallas", pipeline_factory=_fft_pallas_pipeline,
                     schedules=("local", "nfft", "wfft"),
                     description="FFT conv stage graph, Pallas CGEMM kernel")
