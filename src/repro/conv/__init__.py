"""repro.conv — plan/execute convolution engine.

    from repro.conv import plan_conv
    plan = plan_conv(x.shape, k.shape, padding=1)    # cached
    y = plan(x, k)

See docs/conv_api.md for the backend/schedule matrix and migration notes
from the deprecated ``fft_conv2d`` / ``fft_conv2d_pallas`` entry points.
"""
from repro.conv.registry import (
    BackendInfo, ScheduleInfo, register_backend, register_schedule,
    get_backend, get_schedule, available_backends, available_schedules,
)
from repro.conv.epilogue import Epilogue
from repro.conv.plan import (
    ConvPlan, PreparedConv, plan_conv, conv2d,
    plan_cache_info, clear_plan_cache, plan_cache_capacity,
    prepared_cache_info, clear_prepared_cache,
)
from repro.conv.registry import backend_schedule_pairs
from repro.conv.stages import stage_trace
from repro.conv.netplan import (
    NetworkConv, NetworkPlan, NetworkProfile, PreparedNetwork,
    BucketedNetworkPlan, plan_network,
    plan_network_buckets, prepare_network_buckets, bucket_report,
)
from repro.conv.export import (
    ArtifactMismatch, LoadedConv, LoadedNetwork, export_network,
    load_network, plan_fingerprint,
)
from repro.conv.analyze import (
    PlanProfile, CheckReport, Violation, analyze, register_invariant,
    invariants_for,
)
from repro.conv import backends as _backends
from repro.conv import autotune
from repro.conv.autotune import TunedConfig, autotune_info

_backends.register_builtin()

__all__ = [
    "ConvPlan", "PreparedConv", "plan_conv", "conv2d", "Epilogue",
    "NetworkConv", "NetworkPlan", "NetworkProfile", "PreparedNetwork",
    "BucketedNetworkPlan", "plan_network",
    "plan_network_buckets", "prepare_network_buckets", "bucket_report",
    "ArtifactMismatch", "LoadedConv", "LoadedNetwork", "export_network",
    "load_network", "plan_fingerprint",
    "plan_cache_info", "clear_plan_cache", "plan_cache_capacity",
    "prepared_cache_info", "clear_prepared_cache",
    "stage_trace",
    "PlanProfile", "CheckReport", "Violation", "analyze",
    "register_invariant", "invariants_for",
    "autotune", "TunedConfig", "autotune_info",
    "BackendInfo", "ScheduleInfo",
    "register_backend", "register_schedule",
    "get_backend", "get_schedule",
    "available_backends", "available_schedules", "backend_schedule_pairs",
]
