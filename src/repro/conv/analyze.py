"""Plan-lint: jaxpr-level static analysis of convolution plans.

The paper's NUMA-aware claim is *structural*: data reordering plus the
three-level cgemm parallelization bound how many remote accesses
(all-to-alls / reductions) each schedule performs.  That property can be
certified statically — trace the plan, walk the equation graph, count —
instead of measured, and instead of string-matching the jaxpr pretty
printer (which breaks whenever jax changes its formatting).

``analyze(plan)`` traces a ``ConvPlan`` / ``PreparedConv`` to a closed
jaxpr and walks the equation tree — recursing through ``shard_map``
bodies, ``custom_vjp`` / ``custom_jvp`` call jaxprs, ``pjit`` sub-jaxprs
and any other sub-jaxpr-carrying primitive — into a structured
``PlanProfile``:

  * per-collective equation counts (``all_to_all``, ``psum``,
    ``ppermute``, ``all_gather``) and the bytes they move;
  * dtype-flow facts: the operand dtype of every collective (did the
    ``compute_dtype`` cast land *before* the hot collective?), the CGEMM
    operand dtypes (did ``compute_dtype`` actually reach the hot stage?),
    and whether any f64 silently appeared;
  * stage-op invocation counts (via ``stage_trace``);
  * epilogue-fusion facts: the collective/stage-count delta vs the same
    plan with its epilogue stripped (must be zero — fusion is free);
  * prepared-plan elision facts: which stages/collectives a prepared
    execution skips vs the one-shot plan (nfft: stage 2 and one boundary
    all-to-all);
  * an estimated peak live-buffer footprint per rank (liveness walk over
    the traced program).

On top of the profile sits a declarative invariant registry keyed by
``(backend, schedule)`` (``"*"`` wildcards), evaluated by
``analyze(plan).check()``:

    backend x schedule        invariant
    ----------------------    ------------------------------------------
    *        local            0 collectives of any kind
    *        nfft (full)      6 all_to_all (3 boundaries x re/im), 0 psum
    *        nfft (prepared)  4 all_to_all, stage 2 traced zero times
    *        nfft (repl. G)   4 all_to_all (kernel boundary elided)
    *        wfft             exactly the hot psum pair, 0 all_to_all
    *        * + compute_dtype casts placed before the hot collective,
                              CGEMM operands in compute_dtype
    *        * + epilogue     zero extra collectives, zero extra stage ops
    *        *                no f64 anywhere in the traced program
    *        nfft (real)      <= 0.55x the boundary all-to-all bytes of
                              the plan's full-spectrum (complex) twin
    *        wfft (real)      <= 0.55x the hot psum bytes of the twin

Overlapped plans (``overlap="slab:k"``) scale the count rules per slab —
nfft traces ``4k + 2`` all_to_all eqns (D/Z boundaries per slab, kernel
boundary once), wfft ``2k`` psums, each stage op ``k`` times (stage 2
once) — and add two rules of their own: total collective bytes must stay
<= 1.0x the sequential (``overlap="off"``) twin's (the slabs repartition
the rows, they must never re-send them), and on ``fft-pallas`` every
sub-slab's cgemm must resolve the one plan-pinned block config (no
per-slab re-padding).

The real-spectrum rules are *relative*: ``analyze`` traces the same plan
with ``spectrum="complex"`` (``dataclasses.replace`` twin) and compares
collective operand bytes — certifying that the compact Hermitian packing
actually halves what the wires move, not merely that it exists.

``python -m repro.conv.analyze --check`` sweeps every registered
backend x schedule pair over the paper geometries
(``configs/paper_convs.py``) x {full, prepared, fused-epilogue,
compute-dtype, complex-spectrum} variants and exits non-zero on any
violation — the CI gate
that keeps future perf work honest.  ``seeded_violation(...)`` breaks the
pipelines on purpose so the gate itself is testable.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import sys
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.compat import jaxpr_types

COLLECTIVES = ("all_to_all", "psum", "ppermute", "all_gather")


# --------------------------------------------------------------------------
# Jaxpr walking (structural, pretty-printer-independent)
# --------------------------------------------------------------------------

def _sub_jaxprs(eqn):
    """Every sub-jaxpr a primitive carries, whatever the param is called
    (``jaxpr`` for pjit/shard_map, ``fun_jaxpr`` for custom_vjp,
    ``call_jaxpr`` for custom_jvp/xla_call, ``branches`` for cond, ...)."""
    Jaxpr, ClosedJaxpr = jaxpr_types()
    for val in eqn.params.values():
        items = val if isinstance(val, (tuple, list)) else (val,)
        for item in items:
            if isinstance(item, ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, Jaxpr):
                yield item


def _aval_bytes(aval) -> int:
    try:
        return int(aval.size) * int(aval.dtype.itemsize)
    except (AttributeError, TypeError):
        return 0


def _walk(jaxpr, visit: Callable[[Any], None]) -> None:
    """Depth-first visit of every equation, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        visit(eqn)
        for sub in _sub_jaxprs(eqn):
            _walk(sub, visit)


def _peak_live_bytes(jaxpr) -> int:
    """Estimated peak of simultaneously-live buffer bytes in a traced
    program (liveness walk: a value lives from its defining equation to
    its last use).  Inside ``shard_map`` bodies the avals are per-rank, so
    for sharded schedules this is a per-rank footprint estimate; an
    equation carrying a sub-jaxpr contributes its own peak on top of the
    caller's live set."""
    last_use: Dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if not hasattr(v, "val"):          # skip Literals
                last_use[v] = i
    n = len(jaxpr.eqns)
    for v in jaxpr.outvars:
        if not hasattr(v, "val"):
            last_use[v] = n
    live: Dict[Any, int] = {
        v: _aval_bytes(v.aval)
        for v in (*jaxpr.constvars, *jaxpr.invars) if not hasattr(v, "val")
    }
    cur = sum(live.values())
    peak = cur
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            b = _aval_bytes(v.aval)
            live[v] = b
            cur += b
        sub_peak = max((_peak_live_bytes(s) for s in _sub_jaxprs(eqn)),
                       default=0)
        peak = max(peak, cur + sub_peak)
        for v in [v for v, j in last_use.items() if j <= i]:
            cur -= live.pop(v, 0)
            del last_use[v]
        for v in [v for v in eqn.outvars if v in live and v not in last_use]:
            cur -= live.pop(v)                 # dead outputs free at once
    return peak


# --------------------------------------------------------------------------
# PlanProfile
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Violation:
    invariant: str
    message: str

    def __str__(self) -> str:
        return f"[{self.invariant}] {self.message}"


@dataclasses.dataclass(frozen=True, eq=False)
class CheckReport:
    """Result of evaluating the invariant registry against a profile."""
    profile: "PlanProfile"
    violations: Tuple[Violation, ...]
    checked: Tuple[str, ...]                   # invariant names evaluated

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_failed(self) -> "CheckReport":
        if self.violations:
            detail = "\n  ".join(str(v) for v in self.violations)
            raise AssertionError(
                f"plan-lint: {self.profile.describe_key()} violates "
                f"{len(self.violations)} invariant(s):\n  {detail}")
        return self


@dataclasses.dataclass(frozen=True, eq=False)
class PlanProfile:
    """Structured static-analysis facts for one traced plan execution."""
    backend: str
    schedule: str
    prepared: bool
    is_pipeline: bool                          # stage-graph backend
    replicate_kernel_transform: bool
    epilogue: str                              # Epilogue.describe()
    compute_dtype: Optional[str]               # canonical name or None
    collectives: Dict[str, int]                # name -> eqn count
    collective_dtypes: Dict[str, Dict[str, int]]   # name -> dtype -> count
    collective_bytes: int                      # operand bytes entering them
    stage_counts: Dict[str, int]               # trace-time stage-op counts
    cgemm_dtypes: Tuple[str, ...]              # operand dtypes at stage 3
    has_f64: bool
    peak_live_bytes: int
    n_eqns: int
    epilogue_delta: Optional[Dict[str, Dict[str, int]]] = None
    elision: Optional[Dict[str, int]] = None   # full minus prepared counts
    spectrum: str = "real"                     # plan frequency layout
    spectrum_delta: Optional[Dict[str, Any]] = None  # vs complex twin
    overlap: str = "off"                       # plan overlap knob (resolved)
    num_slabs: int = 1                         # sub-slab count (1 = off)
    blocks: Optional[Tuple] = None             # plan (bm, bn, bk) pins
    cgemm_shapes: Tuple = ()                   # distinct (M, N, K) at stage 3
    overlap_delta: Optional[Dict[str, Any]] = None   # vs sequential twin

    def describe_key(self) -> str:
        tags = [self.backend, self.schedule]
        if self.prepared:
            tags.append("prepared")
        if self.num_slabs > 1:
            tags.append(self.overlap)
        if self.spectrum != "real":
            tags.append(self.spectrum)
        if self.epilogue != "none":
            tags.append(f"ep={self.epilogue}")
        if self.compute_dtype:
            tags.append(self.compute_dtype)
        return "/".join(tags)

    def check(self, *, extra=()) -> CheckReport:
        """Evaluate every registered invariant applying to this profile."""
        violations: List[Violation] = []
        invs = list(invariants_for(self.backend, self.schedule)) + list(extra)
        for inv in invs:
            msg = inv.rule(self)
            if msg:
                violations.append(Violation(inv.name, msg))
        return CheckReport(profile=self, violations=tuple(violations),
                           checked=tuple(i.name for i in invs))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["cgemm_dtypes"] = list(self.cgemm_dtypes)
        d["blocks"] = list(self.blocks) if self.blocks else None
        d["cgemm_shapes"] = [list(s) for s in self.cgemm_shapes]
        return d


# --------------------------------------------------------------------------
# Invariant registry (declarative, keyed backend x schedule)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Invariant:
    """One named structural rule.  ``rule(profile)`` returns ``None`` when
    the invariant holds, else a human-readable violation message."""
    name: str
    rule: Callable[[PlanProfile], Optional[str]]
    description: str = ""


_REGISTRY: Dict[Tuple[str, str], List[Invariant]] = {}


def register_invariant(backend: str, schedule: str, name: str,
                       rule: Callable[[PlanProfile], Optional[str]],
                       description: str = "") -> Invariant:
    """Register a structural invariant for ``(backend, schedule)``;
    ``"*"`` wildcards either key.  Third-party backends registered via
    ``repro.conv.register_backend`` add their rules here so the
    ``--check`` sweep certifies them too."""
    inv = Invariant(name=name, rule=rule, description=description)
    _REGISTRY.setdefault((backend, schedule), []).append(inv)
    return inv


def invariants_for(backend: str, schedule: str) -> Tuple[Invariant, ...]:
    out: List[Invariant] = []
    for key in (("*", "*"), ("*", schedule), (backend, "*"),
                (backend, schedule)):
        out.extend(_REGISTRY.get(key, ()))
    return tuple(out)


def _expect_counts(**expected):
    """Rule factory: exact collective-equation counts.  Values are ints or
    ``callable(profile) -> int`` for prepared/replicated variants."""
    def rule(p: PlanProfile) -> Optional[str]:
        bad = []
        for name, want in expected.items():
            want_n = want(p) if callable(want) else want
            got = p.collectives.get(name, 0)
            if got != want_n:
                bad.append(f"{name}: expected {want_n}, traced {got}")
        return "; ".join(bad) or None
    return rule


def _nfft_a2a(p: PlanProfile) -> int:
    # per slab: D boundary #1 + Z boundary #3 (re/im pairs = 4 eqns);
    # kernel boundary #2 is shared by all slabs and traced once (2 eqns) —
    # prepared elides it (stage 2 was paid at prepare time) and
    # replicate_kernel_transform never emits it.  num_slabs=1 recovers the
    # sequential 6 full / 4 prepared-or-replicated counts.
    s = max(1, p.num_slabs)
    return 4 * s + (0 if (p.prepared or p.replicate_kernel_transform)
                    else 2)


def _wfft_psum(p: PlanProfile) -> int:
    # the hot-stage all-reduce pair, once per sub-slab
    return 2 * max(1, p.num_slabs)


def _rule_local_collective_free(p: PlanProfile) -> Optional[str]:
    extra = {k: v for k, v in p.collectives.items() if v}
    if extra:
        return f"local schedule traced collectives: {extra}"
    return None


def _rule_stage_ops_once(p: PlanProfile) -> Optional[str]:
    if not p.is_pipeline:
        return None
    s = max(1, p.num_slabs)
    # stages 1/3/4 run once per sub-slab; the kernel transform is shared
    # by all slabs (never duplicated) and elided entirely when prepared
    want = {"input_transform": s, "cgemm": s, "output_inverse": s,
            "kernel_transform": 0 if p.prepared else 1}
    bad = [f"{k}: expected {v}, traced {p.stage_counts.get(k, 0)}"
           for k, v in want.items() if p.stage_counts.get(k, 0) != v]
    return "; ".join(bad) or None


def _rule_no_f64(p: PlanProfile) -> Optional[str]:
    if p.has_f64:
        return "f64 values appeared in the traced program (silent upcast)"
    return None


def _rule_compute_dtype_reaches_cgemm(p: PlanProfile) -> Optional[str]:
    if p.compute_dtype is None or not p.is_pipeline:
        return None
    if set(p.cgemm_dtypes) != {p.compute_dtype}:
        return (f"CGEMM operands traced as {sorted(set(p.cgemm_dtypes))}, "
                f"expected compute_dtype={p.compute_dtype}")
    return None


def _rule_cast_before_hot_collective(hot: str, expected_n):
    """The compute_dtype cast must land BEFORE the hot collective so it
    moves half the bytes: ``expected_n`` of the ``hot`` collective's
    equations must carry operands in compute_dtype."""
    def rule(p: PlanProfile) -> Optional[str]:
        if p.compute_dtype is None:
            return None
        want = expected_n(p) if callable(expected_n) else expected_n
        got = p.collective_dtypes.get(hot, {}).get(p.compute_dtype, 0)
        if got != want:
            return (f"{hot} in {p.compute_dtype}: expected {want} eqns, "
                    f"traced {got} "
                    f"(dtypes seen: {p.collective_dtypes.get(hot, {})})")
        return None
    return rule


def _rule_epilogue_free(p: PlanProfile) -> Optional[str]:
    if not p.epilogue_delta:
        return None
    bad = []
    for kind, deltas in p.epilogue_delta.items():
        extra = {k: v for k, v in deltas.items() if v}
        if extra:
            bad.append(f"epilogue added {kind}: {extra}")
    return "; ".join(bad) or None


_RFFT_BYTES_RATIO = 0.55


def _rule_rfft_halves_collective_bytes(p: PlanProfile) -> Optional[str]:
    if p.spectrum != "real" or not p.spectrum_delta:
        return None
    ratio = p.spectrum_delta.get("ratio")
    if ratio is not None and ratio > _RFFT_BYTES_RATIO:
        return (f"real-spectrum plan moves {ratio:.4f}x the collective "
                f"bytes of its full-spectrum twin "
                f"({p.spectrum_delta.get('collective_bytes')} vs "
                f"{p.spectrum_delta.get('twin_collective_bytes')}); the "
                f"compact Hermitian packing must stay <= "
                f"{_RFFT_BYTES_RATIO}x")
    return None


def _rule_prepared_elides_boundary(p: PlanProfile) -> Optional[str]:
    if not (p.prepared and p.elision):
        return None
    if p.elision.get("all_to_all", 0) != 2:
        return (f"prepared nfft must skip exactly one boundary all-to-all "
                f"(re/im pair); elision traced {p.elision}")
    return None


# Overlapped execution repartitions the batch rows across sub-slab
# collectives — it must never re-send them.  Exact parity is expected
# (the per-slab paddings are proportional); the epsilon only absorbs
# float division.
_OVERLAP_BYTES_RATIO = 1.005


def _rule_overlap_bytes_parity(p: PlanProfile) -> Optional[str]:
    if p.num_slabs <= 1 or not p.overlap_delta:
        return None
    ratio = p.overlap_delta.get("ratio")
    if ratio is not None and ratio > _OVERLAP_BYTES_RATIO:
        return (f"overlapped plan moves {ratio:.4f}x the collective bytes "
                f"of its sequential (overlap='off') twin "
                f"({p.overlap_delta.get('collective_bytes')} vs "
                f"{p.overlap_delta.get('twin_collective_bytes')}); "
                f"sub-slabbing must repartition rows, not duplicate them")
    return None


def _rule_overlap_uniform_blocks(p: PlanProfile) -> Optional[str]:
    """Every sub-slab's cgemm must resolve to the ONE block config pinned
    at plan time — differing per-slab resolutions mean distinct compiled
    kernels and re-padding on every call (the bug the plan-time clamp
    fixes)."""
    if p.num_slabs <= 1 or not p.cgemm_shapes:
        return None
    from repro.kernels.cgemm.ops import resolve_blocks
    bm, bn, bk = p.blocks if p.blocks else (None, None, None)
    resolved = {resolve_blocks(m, n, c, bm, bn, bk)
                for (m, n, c) in p.cgemm_shapes}
    if len(resolved) > 1:
        return (f"sub-slab cgemm shapes {sorted(p.cgemm_shapes)} resolve "
                f"different block configs {sorted(resolved)}; blocks must "
                f"be clamped once at plan time")
    rbm = next(iter(resolved))[0]
    m_min = min(m for m, _, _ in p.cgemm_shapes)
    lane_fit = -(-m_min // 8) * 8
    if rbm > lane_fit:
        return (f"resolved bm={rbm} exceeds the smallest sub-slab's "
                f"lane-aligned rows (M={m_min} -> {lane_fit}): the small "
                f"slabs re-pad on every call")
    return None


def _register_builtin_invariants() -> None:
    register_invariant(
        "*", "local", "local-collective-free", _rule_local_collective_free,
        "the local schedule performs zero collectives of any kind")
    register_invariant(
        "*", "nfft", "nfft-a2a-count",
        _expect_counts(all_to_all=_nfft_a2a, psum=0, ppermute=0,
                       all_gather=0),
        "tuple partitioning: one a2a pair per live stage boundary and a "
        "collective-free hot CGEMM (6 full / 4 prepared or replicated; "
        "the D/Z boundary pairs scale per sub-slab when overlapped)")
    register_invariant(
        "*", "nfft", "nfft-prepared-elision", _rule_prepared_elides_boundary,
        "prepared nfft skips stage 2 AND boundary all-to-all #2")
    register_invariant(
        "*", "nfft", "nfft-hot-cast",
        _rule_cast_before_hot_collective("all_to_all",
                                         lambda p: 4 * max(1, p.num_slabs)),
        "compute_dtype cast lands before the D/Z boundary a2a pairs "
        "(the kernel boundary stays f32)")
    register_invariant(
        "*", "wfft", "wfft-hot-psum-pair",
        _expect_counts(psum=_wfft_psum, all_to_all=0, ppermute=0,
                       all_gather=0),
        "baseline: exactly the hot-stage all-reduce pair (per sub-slab "
        "when overlapped), nothing else")
    register_invariant(
        "*", "wfft", "wfft-hot-cast",
        _rule_cast_before_hot_collective("psum", _wfft_psum),
        "compute_dtype cast lands before the hot-stage psum pair")
    register_invariant(
        "*", "nfft", "nfft-rfft-halves-a2a",
        _rule_rfft_halves_collective_bytes,
        "the compact half-spectrum nfft plan moves <= 0.55x the boundary "
        "all-to-all bytes of its full-spectrum (complex) twin")
    register_invariant(
        "*", "wfft", "wfft-rfft-halves-psum",
        _rule_rfft_halves_collective_bytes,
        "the compact half-spectrum wfft plan moves <= 0.55x the hot psum "
        "bytes of its full-spectrum (complex) twin")
    register_invariant(
        "*", "*", "stage-ops-once", _rule_stage_ops_once,
        "each pipeline stage op traces exactly once (stage 2 zero times "
        "when prepared)")
    register_invariant(
        "*", "*", "no-f64", _rule_no_f64,
        "no silent f64 upcast anywhere in the traced program")
    register_invariant(
        "*", "*", "compute-dtype-reaches-cgemm",
        _rule_compute_dtype_reaches_cgemm,
        "compute_dtype actually reaches the hot CGEMM operands")
    register_invariant(
        "*", "*", "epilogue-fusion-free", _rule_epilogue_free,
        "a fused epilogue adds zero collectives and zero stage ops")
    register_invariant(
        "*", "*", "overlap-bytes-parity", _rule_overlap_bytes_parity,
        "an overlapped plan's total collective bytes stay <= 1.0x its "
        "sequential (overlap='off') twin's — sub-slabbing repartitions "
        "the rows, it never re-sends them")
    register_invariant(
        "fft-pallas", "*", "overlap-uniform-blocks",
        _rule_overlap_uniform_blocks,
        "every sub-slab's cgemm resolves the one plan-pinned block "
        "config (no per-slab re-resolution / re-padding)")


_register_builtin_invariants()


# --------------------------------------------------------------------------
# Tracing -> PlanProfile
# --------------------------------------------------------------------------

def _canon_dtype(dt) -> Optional[str]:
    if dt is None:
        return None
    import numpy as np
    return str(np.dtype(dt))


def _epilogue_arg_structs(plan):
    import jax
    import jax.numpy as jnp
    keys, structs = [], []
    if plan.epilogue.bias:
        keys.append("bias")
        structs.append(jax.ShapeDtypeStruct((plan.spec.Cout,), jnp.float32))
    if plan.epilogue.residual:
        keys.append("residual")
        structs.append(jax.ShapeDtypeStruct(plan.out_shape, jnp.float32))
    return keys, structs


def _trace_full(plan):
    """Jaxpr + stage counts of the one-shot ``plan(x, k)`` path.  The
    closure is built fresh on every call: jax memoizes custom-VJP traces
    per (plan, avals), and a reused callable would skip the Python-level
    stage counters on the second trace."""
    import jax
    import jax.numpy as jnp
    from repro.conv.stages import stage_trace
    keys, ep_structs = _epilogue_arg_structs(plan)
    args = [jax.ShapeDtypeStruct(plan.x_shape, jnp.float32),
            jax.ShapeDtypeStruct(plan.k_shape, jnp.float32), *ep_structs]
    with stage_trace() as counts:
        jaxpr = jax.make_jaxpr(
            lambda x, k, *ep: plan(x, k, **dict(zip(keys, ep))))(*args)
    return jaxpr, dict(counts)


def _trace_prepared(plan, state=None):
    """Jaxpr + stage counts of the prepared-execute path.  With no
    concrete ``state`` the prepared kernel layout is derived abstractly
    (``jax.eval_shape`` over the pipeline's ``prepare``) so no transform
    FLOPs run — analysis stays static."""
    import jax
    import jax.numpy as jnp
    from repro.conv import registry
    from repro.conv.stages import stage_trace
    be = registry.get_backend(plan.backend)
    k_struct = jax.ShapeDtypeStruct(plan.k_shape, jnp.float32)
    if be.pipeline_factory is not None:
        pipe = be.make_pipeline(plan)
        if state is None:
            state = jax.eval_shape(lambda k: pipe.prepare(plan, k), k_struct)

        def run(x, st, bias=None, residual=None):
            return pipe.execute(plan, x, st, bias=bias, residual=residual)
    else:
        if state is None:
            state = k_struct                  # opaque: state IS the kernel

        def run(x, st, bias=None, residual=None):
            if plan.epilogue.is_noop:
                return be.execute(plan, x, st)
            return be.execute(plan, x, st, bias=bias, residual=residual)

    state_structs = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    keys, ep_structs = _epilogue_arg_structs(plan)
    args = [jax.ShapeDtypeStruct(plan.x_shape, jnp.float32), state_structs,
            *ep_structs]
    with stage_trace() as counts:
        jaxpr = jax.make_jaxpr(
            lambda x, st, *ep: run(x, st, **dict(zip(keys, ep))))(*args)
    return jaxpr, dict(counts)


def _profile_from_trace(plan, jaxpr, counts, *, prepared: bool):
    import numpy as np
    from repro.conv import registry
    colls = {name: 0 for name in COLLECTIVES}
    coll_dtypes: Dict[str, Dict[str, int]] = {}
    coll_bytes = 0
    f64 = [False]

    def visit(eqn):
        name = eqn.primitive.name
        for v in (*eqn.invars, *eqn.outvars):
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and np.dtype(dt).itemsize == 8 and \
                    np.issubdtype(np.dtype(dt), np.floating):
                f64[0] = True
        if name in colls:
            colls[name] += 1
            nonlocal coll_bytes
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                coll_bytes += _aval_bytes(aval)
            dt = _canon_dtype(getattr(eqn.invars[0].aval, "dtype", None))
            if dt is not None:
                coll_dtypes.setdefault(name, {})
                coll_dtypes[name][dt] = coll_dtypes[name].get(dt, 0) + 1

    n_eqns = [0]

    def visit_all(eqn):
        n_eqns[0] += 1
        visit(eqn)

    _walk(jaxpr.jaxpr, visit_all)
    stage_counts = {k: v for k, v in counts.items() if isinstance(k, str)}
    cgemm_dtypes = tuple(sorted(
        k[1] for k in counts if isinstance(k, tuple) and k[0] == "cgemm_dtype"
    ))
    cgemm_shapes = tuple(sorted(
        k[1] for k in counts if isinstance(k, tuple) and k[0] == "cgemm_shape"
    ))
    be = registry.get_backend(plan.backend)
    return PlanProfile(
        backend=plan.backend, schedule=plan.schedule, prepared=prepared,
        is_pipeline=be.pipeline_factory is not None,
        replicate_kernel_transform=plan.replicate_kernel_transform,
        epilogue=plan.epilogue.describe(),
        compute_dtype=_canon_dtype(plan.compute_dtype),
        collectives=colls, collective_dtypes=coll_dtypes,
        collective_bytes=coll_bytes, stage_counts=stage_counts,
        cgemm_dtypes=cgemm_dtypes, has_f64=f64[0],
        peak_live_bytes=_peak_live_bytes(jaxpr.jaxpr), n_eqns=n_eqns[0],
        spectrum=getattr(plan, "spectrum", "real"),
        overlap=getattr(plan, "overlap", "off"),
        num_slabs=getattr(plan, "num_slabs", 1),
        blocks=(plan.bm, plan.bn, plan.bk), cgemm_shapes=cgemm_shapes)


def analyze(target, *, prepared: bool = False) -> PlanProfile:
    """Statically analyze a ``ConvPlan``, ``PreparedConv`` or
    ``NetworkPlan`` into a structured profile (no conv FLOPs run — the
    plan is traced abstractly and the equation tree is walked).

    ``analyze(plan)`` profiles the one-shot path; ``analyze(plan,
    prepared=True)`` profiles the prepared-execute path with the kernel
    layout derived abstractly; ``analyze(prepared_conv)`` profiles an
    existing prepared plan.  Evaluate the invariant registry with
    ``analyze(...).check()``.
    """
    from repro.conv.netplan import NetworkPlan
    from repro.conv.plan import ConvPlan, PreparedConv
    if isinstance(target, NetworkPlan):
        return target.analyze()
    if isinstance(target, PreparedConv):
        plan, state, prepared = target.plan, target.state, True
    elif isinstance(target, ConvPlan):
        plan, state = target, None
    else:
        raise TypeError(
            f"analyze() takes a ConvPlan, PreparedConv or NetworkPlan; "
            f"got {type(target).__name__}")

    if not prepared:
        jaxpr, counts = _trace_full(plan)
        profile = _profile_from_trace(plan, jaxpr, counts, prepared=False)
    else:
        jaxpr, counts = _trace_prepared(plan, state)
        profile = _profile_from_trace(plan, jaxpr, counts, prepared=True)
        full = _profile_from_trace(plan, *_trace_full(plan), prepared=False)
        elision = {
            name: full.collectives.get(name, 0)
            - profile.collectives.get(name, 0) for name in COLLECTIVES}
        elision["kernel_transform"] = \
            full.stage_counts.get("kernel_transform", 0) \
            - profile.stage_counts.get("kernel_transform", 0)
        profile = dataclasses.replace(profile, elision=elision)

    if not plan.epilogue.is_noop:
        from repro.conv.epilogue import Epilogue
        bare = dataclasses.replace(plan, epilogue=Epilogue())
        if prepared:
            bp = _profile_from_trace(bare, *_trace_prepared(bare),
                                     prepared=True)
        else:
            bp = _profile_from_trace(bare, *_trace_full(bare),
                                     prepared=False)
        delta = {
            "collectives": {
                n: profile.collectives.get(n, 0) - bp.collectives.get(n, 0)
                for n in COLLECTIVES},
            "stage_counts": {
                n: profile.stage_counts.get(n, 0)
                - bp.stage_counts.get(n, 0)
                for n in set(profile.stage_counts) | set(bp.stage_counts)},
        }
        profile = dataclasses.replace(profile, epilogue_delta=delta)

    # Real-spectrum plans on sharded schedules get a bytes-ratio profile
    # against their full-spectrum twin (same plan, spectrum="complex") so
    # the halved-collective-bytes invariant is certified *relatively* —
    # the twin is traced at the same prepared-ness, never executed.
    if profile.is_pipeline and plan.spectrum == "real" \
            and plan.schedule in ("nfft", "wfft"):
        twin = dataclasses.replace(plan, spectrum="complex")
        if prepared:
            tp = _profile_from_trace(twin, *_trace_prepared(twin),
                                     prepared=True)
        else:
            tp = _profile_from_trace(twin, *_trace_full(twin),
                                     prepared=False)
        ratio = (profile.collective_bytes / tp.collective_bytes
                 if tp.collective_bytes else None)
        profile = dataclasses.replace(profile, spectrum_delta={
            "collective_bytes": profile.collective_bytes,
            "twin_collective_bytes": tp.collective_bytes,
            "ratio": ratio})

    # Overlapped plans get a bytes-parity profile against their sequential
    # twin (same plan, overlap="off"): the sub-slab collectives must
    # repartition the rows the synchronous path moves, never re-send them.
    if profile.is_pipeline and profile.num_slabs > 1:
        seq = dataclasses.replace(plan, overlap="off")
        if prepared:
            sq = _profile_from_trace(seq, *_trace_prepared(seq),
                                     prepared=True)
        else:
            sq = _profile_from_trace(seq, *_trace_full(seq), prepared=False)
        ratio = (profile.collective_bytes / sq.collective_bytes
                 if sq.collective_bytes else None)
        profile = dataclasses.replace(profile, overlap_delta={
            "collective_bytes": profile.collective_bytes,
            "twin_collective_bytes": sq.collective_bytes,
            "ratio": ratio,
            "collectives": dict(profile.collectives),
            "twin_collectives": dict(sq.collectives)})
    return profile


# --------------------------------------------------------------------------
# Seeded violations (negative testing of the gate itself)
# --------------------------------------------------------------------------

VIOLATION_MODES = ("extra-collective", "extra-stage", "skip-cast",
                   "rfft-unpacked", "overlap-oversend")


@contextlib.contextmanager
def seeded_violation(mode: str = "extra-collective"):
    """Deliberately break the stage pipelines so ``--check`` has something
    to catch (negative self-test of the gate; never use outside tests).

      extra-collective  every nfft boundary all-to-all also psums (the
                        hot path gains reductions it must not have);
      extra-stage       the kernel transform runs twice per trace;
      skip-cast         compute_dtype casts silently dropped (collectives
                        move full-width bytes again);
      rfft-unpacked     the compact-Hermitian pack degrades to a plain
                        half-plane flatten — real-spectrum plans ship the
                        redundant self-conjugate rows again and the
                        bytes-ratio invariants must trip;
      overlap-oversend  every sub-slab collective pads its M rows 2x
                        before the wire and slices back after — only
                        overlapped plans are hit (the sequential twin is
                        untouched), so the overlap-bytes-parity invariant
                        must trip.
    """
    from repro.conv import stages
    if mode == "overlap-oversend":
        import jax.numpy as jnp
        orig_a2a = stages._slab_a2a
        orig_psum = stages._slab_psum

        def _oversend(T):
            return jnp.concatenate([T, jnp.zeros_like(T)], axis=1)

        def broken_a2a(Tr, Ti, axis_name, split, concat):
            m = Tr.shape[1]          # M rides axis 1 across both boundaries
            Tr, Ti = orig_a2a(_oversend(Tr), _oversend(Ti), axis_name,
                              split, concat)
            return Tr[:, :m], Ti[:, :m]

        def broken_psum(Zr, Zi, axis_name):
            m = Zr.shape[1]
            Zr, Zi = orig_psum(_oversend(Zr), _oversend(Zi), axis_name)
            return Zr[:, :m], Zi[:, :m]

        stages._slab_a2a = broken_a2a
        stages._slab_psum = broken_psum
        try:
            yield
        finally:
            stages._slab_a2a = orig_a2a
            stages._slab_psum = orig_psum
    elif mode == "extra-collective":
        import jax
        orig = stages._boundary_a2a

        def broken(Tr, Ti, axis_name, split, concat):
            Tr, Ti = orig(Tr, Ti, axis_name, split, concat)
            return jax.lax.psum(Tr, axis_name), jax.lax.psum(Ti, axis_name)

        stages._boundary_a2a = broken
        try:
            yield
        finally:
            stages._boundary_a2a = orig
    elif mode == "extra-stage":
        orig = stages.stage_kernel_transform

        def broken(k, spec, spectrum="rect"):
            orig(k, spec, spectrum)
            return orig(k, spec, spectrum)

        stages.stage_kernel_transform = broken
        try:
            yield
        finally:
            stages.stage_kernel_transform = orig
    elif mode == "rfft-unpacked":
        from repro.core import fftconv

        orig = fftconv.pack_half_spectrum

        def broken(Tr, Ti, delta):
            # keep the full half-plane (delta x (delta//2+1)) flattened:
            # shape-consistent downstream (unpack reads a prefix) but the
            # redundant conjugate rows ride every collective again
            return (Tr.reshape(*Tr.shape[:-2], -1),
                    Ti.reshape(*Ti.shape[:-2], -1))

        fftconv.pack_half_spectrum = broken
        try:
            yield
        finally:
            fftconv.pack_half_spectrum = orig
    elif mode == "skip-cast":
        orig = stages._maybe_cast

        def broken(pair, dtype):
            return pair

        stages._maybe_cast = broken
        try:
            yield
        finally:
            stages._maybe_cast = orig
    else:
        raise ValueError(
            f"unknown violation mode {mode!r}; known: {VIOLATION_MODES}")


# --------------------------------------------------------------------------
# CLI: sweep every backend x schedule over the paper geometries
# --------------------------------------------------------------------------

def _paper_geometries(batch: int, limit: Optional[int] = None):
    """Table-I layers as (name, x_shape, k_shape, padding).  Structure is
    batch-invariant, so the sweep uses a small batch to keep tracing
    fast; ``limit`` trims the set for quick runs."""
    from repro.configs.paper_convs import TABLE1
    layers = TABLE1[:limit] if limit else TABLE1
    return [(l.name, (batch, l.C, l.H, l.W), (l.Cout, l.C, l.kh, l.kw),
             l.pad) for l in layers]


def sweep(*, batch: int = 4, limit: Optional[int] = None,
          compute_dtype="bfloat16", progress=print, pairs=None):
    """Profile + check every registered backend x schedule pair over the
    paper geometries x {full, prepared, fused-epilogue, compute-dtype,
    full-spectrum (complex), overlapped (slab:2)} variants.  Returns
    ``(profiles, violations)`` where ``profiles`` maps
    ``"backend/schedule/layer/variant"`` to a ``PlanProfile``.  ``pairs``
    restricts the sweep to a subset of (backend, schedule) pairs — the
    ``--jobs`` process-parallel tracer partitions the registry this way."""
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.conv import registry
    from repro.conv.epilogue import Epilogue
    from repro.conv.plan import plan_conv

    mesh = None
    profiles: Dict[str, PlanProfile] = {}
    violations: List[Tuple[str, Violation]] = []
    cdt = jnp.dtype(compute_dtype) if compute_dtype else None
    if pairs is None:
        pairs = registry.backend_schedule_pairs()
    for backend, schedule in pairs:
        needs_mesh = registry.get_schedule(schedule).requires_mesh
        if needs_mesh and mesh is None:
            mesh = make_mesh((1, 1), ("data", "model"))
        for name, x_shape, k_shape, padding in _paper_geometries(batch,
                                                                 limit):
            base = dict(padding=padding, backend=backend, schedule=schedule,
                        mesh=mesh if needs_mesh else None)
            variants = [
                ("full", {}, False),
                ("prepared", {}, True),
                ("epilogue",
                 {"epilogue": Epilogue(bias=True, activation="relu")},
                 False),
            ]
            if cdt is not None:
                variants.append(("cdtype", {"compute_dtype": cdt}, False))
            if registry.get_backend(backend).pipeline_factory is not None:
                # the full-spectrum twin is a legal plan in its own right
                # — certify it directly, not only as a ratio baseline
                variants.append(("complex", {"spectrum": "complex"}, False))
                if needs_mesh:
                    # overlapped sub-slab execution: slab-scaled collective
                    # counts + bytes parity vs the sequential twin
                    variants.append(("overlap", {"overlap": "slab:2"},
                                     False))
            for variant, extra, as_prepared in variants:
                key = f"{backend}/{schedule}/{name}/{variant}"
                plan = plan_conv(x_shape, k_shape, **base, **extra)
                profile = analyze(plan, prepared=as_prepared)
                profiles[key] = profile
                report = profile.check()
                for v in report.violations:
                    violations.append((key, v))
                    progress(f"VIOLATION {key}: {v}")
    return profiles, violations


def _sweep_worker(payload):
    """Module-level (picklable) worker for ``--jobs``: sweep a subset of
    the backend x schedule pairs in a spawned process, returning plain
    JSON-able results (profiles as dicts, violations as tuples)."""
    pairs, batch, limit, inject = payload
    ctx = seeded_violation(inject) if inject else contextlib.nullcontext()
    with ctx:
        profiles, violations = sweep(batch=batch, limit=limit, pairs=pairs,
                                     progress=lambda s: None)
    return ({k: p.to_dict() for k, p in profiles.items()},
            [(k, v.invariant, v.message) for k, v in violations])


def _sweep_parallel(jobs: int, batch: int, limit, inject):
    """Partition the registered pairs round-robin over ``jobs`` spawned
    processes (each re-imports jax cleanly — seeded violations are applied
    inside the worker, after its own module state exists)."""
    import multiprocessing as mp
    from repro.conv import registry
    pairs = list(registry.backend_schedule_pairs())
    chunks = [c for c in (pairs[i::jobs] for i in range(jobs)) if c]
    ctx = mp.get_context("spawn")
    with ctx.Pool(processes=len(chunks)) as pool:
        results = pool.map(_sweep_worker,
                           [(c, batch, limit, inject) for c in chunks])
    payload: Dict[str, dict] = {}
    violations: List[Tuple[str, str, str]] = []
    for prof, viols in results:
        payload.update(prof)
        violations.extend(viols)
    return payload, violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.conv.analyze",
        description="Plan-lint: certify the conv engine's structural "
                    "invariants (collectives / dtype flow / fusion) for "
                    "every registered backend x schedule.")
    ap.add_argument("--check", action="store_true",
                    help="sweep backend x schedule x paper geometries and "
                         "exit non-zero on any violated invariant")
    ap.add_argument("--batch", type=int, default=4,
                    help="trace batch size (structure is batch-invariant)")
    ap.add_argument("--limit", type=int, default=None,
                    help="only the first N Table-I geometries")
    ap.add_argument("--json-out", default="",
                    help="write every profile as JSON to this path")
    ap.add_argument("--inject", choices=VIOLATION_MODES, default=None,
                    help="seed a deliberate pipeline violation first "
                         "(negative self-test: --check must then FAIL)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-parallel tracing: partition the backend "
                         "x schedule pairs over N spawned workers (the "
                         "full sweep is tracing-bound)")
    args = ap.parse_args(argv)
    if not args.check and not args.json_out:
        ap.print_help()
        return 2

    if args.jobs > 1:
        payload, raw_violations = _sweep_parallel(
            args.jobs, args.batch, args.limit, args.inject)
        for key, inv, msg in raw_violations:
            print(f"VIOLATION {key}: [{inv}] {msg}")
        n_violations = len(raw_violations)
    else:
        ctx = seeded_violation(args.inject) if args.inject \
            else contextlib.nullcontext()
        with ctx:
            profiles, violations = sweep(batch=args.batch, limit=args.limit)
        payload = {k: p.to_dict() for k, p in profiles.items()}
        n_violations = len(violations)

    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        print(f"# wrote {len(payload)} profiles to {args.json_out}")

    n = len(payload)
    if n_violations:
        print(f"plan-lint: {n_violations} violation(s) across "
              f"{n} profiles", file=sys.stderr)
        return 1
    print(f"plan-lint: OK — {n} profiles, 0 violations "
          f"(invariants certified for "
          f"{len({(d['backend'], d['schedule']) for d in payload.values()})} "
          f"backend x schedule pairs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
