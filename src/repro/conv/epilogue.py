"""Fused epilogue spec for the stage-graph convolution engine.

The FFT pipeline is bandwidth-bound, not FLOP-bound (Zlateski et al.), so
any extra elementwise pass over the output — bias add, activation,
residual add — is pure wasted memory traffic.  An ``Epilogue`` freezes
*which* elementwise tail a plan executes; the pipelines fuse it into stage
4 (``stage_output_inverse``) on the local C'/N output slab, before the
f32 -> x.dtype cast and before leaving ``shard_map``, so sharded schedules
do the elementwise work on 1/N of the output with zero extra collectives
and zero extra stage-op invocations.

The operand *values* (the bias vector, the residual tensor) are execution
arguments — ``plan(x, k, bias=b, residual=r)`` — only the *shape* of the
epilogue lives in the plan (and therefore in the plan-cache key).

Semantics (cuDNN-style runtime-fusion order):

    y = activation(conv(x, k) + bias[None, :, None, None] + residual)

i.e. the residual is added *before* the activation (the ResNet basic-block
form ``relu(conv + shortcut)``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


# Activation registry: name -> elementwise callable.  ``gelu`` is the tanh
# approximation so the Pallas kernel tail (no erf) matches bit-for-bit.
ACTIVATIONS = {
    "none": lambda y: y,
    "relu": jax.nn.relu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "silu": jax.nn.silu,
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Frozen spec of the elementwise tail fused into stage 4.

    Hashable and part of the plan-cache key: two plans that differ only in
    their epilogue are distinct cached programs.
    """
    bias: bool = False
    activation: str = "none"        # "none" | "relu" | "gelu" | "silu"
    residual: bool = False

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"unknown epilogue activation {self.activation!r}; "
                f"available: {tuple(sorted(ACTIVATIONS))}")

    @property
    def is_noop(self) -> bool:
        return (not self.bias and self.activation == "none"
                and not self.residual)

    def describe(self) -> str:
        if self.is_noop:
            return "none"
        parts = []
        if self.bias:
            parts.append("bias")
        if self.residual:
            parts.append("residual")
        if self.activation != "none":
            parts.append(self.activation)
        return "+".join(parts)


def apply_epilogue(y, epilogue: Epilogue | None, *, bias=None, residual=None):
    """Apply an epilogue to an output (or output slab) ``y``.

    ``y`` is NCHW-like with channels on axis 1; under a sharded schedule it
    is the *local* C'/N slab and ``bias``/``residual`` are the matching
    local shards (shard_map splits them — no collectives).  Accumulates in
    ``y``'s dtype (f32 at the fusion point, before the output cast).
    """
    if epilogue is None or epilogue.is_noop:
        return y
    if epilogue.bias:
        y = y + bias.astype(y.dtype)[None, :, None, None]
    if epilogue.residual:
        y = y + residual.astype(y.dtype)
    return ACTIVATIONS[epilogue.activation](y)


def activation_vjp(epilogue: Epilogue, z, dy):
    """Cotangent of the activation at pre-activation value ``z``.

    Used by the plan-level VJP: the activation gradient is applied to the
    incoming cotangent *before* it enters the transposed plan / the bias
    reduction.
    """
    if epilogue.activation == "none":
        return dy
    _, vjp = jax.vjp(ACTIVATIONS[epilogue.activation], z)
    (dz,) = vjp(dy.astype(z.dtype))
    return dz


def bias_grad(dz):
    """d_bias: reduce the conv-output cotangent over batch and space."""
    return jnp.sum(dz, axis=(0, 2, 3))
