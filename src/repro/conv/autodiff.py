"""Plan-level reverse-mode autodiff for the stage-graph conv engine.

Differentiability is a property of the *plan*, not of one backend's
implementation: every backend that executes through a stage pipeline gets
the same custom VJP, defined once here over the whole pipeline —

  dx : a *transposed* plan (same backend, schedule, mesh and precision as
       the forward) applied to dy and the spatially-flipped,
       channel-transposed kernel, "full"-correlation padding, cropped by
       the forward padding;
  dk : direct correlation of x with dy, batch as the contraction axis
       (dy's spatial extent exceeds the FFT tile, so the direct path is
       the right algorithm — one oracle call).

Because the backward pass is expressed as plans, it runs through the same
schedules as the forward: the gradient of an ``nfft`` conv is itself an
``nfft`` conv (collectives and all), which is what makes training *through*
the NUMA-aware schedule possible.  The Pallas backend is shielded by the
VJP (its kernel is never differentiated through), so ``fft-pallas`` trains
too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def pipeline_conv(plan, x, k):
    """Differentiable execution of a stage-pipeline plan."""
    return _pipeline(plan).full(plan, x, k)


def _pipeline(plan):
    from repro.conv import registry
    return registry.get_backend(plan.backend).make_pipeline(plan)


def _transposed_plan(plan):
    """The plan computing dx: conv of dy (B, C', Ho, Wo) with the flipped,
    transposed kernel (C, C', kh, kw) at full-correlation padding, on the
    same backend x schedule (and mesh/precision knobs) as the forward."""
    from repro.conv.plan import plan_conv
    s = plan.spec
    return plan_conv(
        (s.B, s.Cout, s.Ho, s.Wo), (s.C, s.Cout, s.kh, s.kw),
        padding=(s.kh - 1, s.kw - 1), delta=s.delta, backend=plan.backend,
        schedule=plan.schedule, mesh=plan.mesh, three_m=plan.three_m,
        bm=plan.bm, bn=plan.bn, bk=plan.bk,
        compute_dtype=plan.compute_dtype, data_axis=plan.data_axis,
        model_axis=plan.model_axis,
        replicate_kernel_transform=plan.replicate_kernel_transform)


def _dx_via_transposed_plan(plan, k, dy):
    """dx: transposed plan on the flipped/channel-transposed kernel; the
    recursive pipeline_conv call keeps higher-order grads working."""
    s, pad = plan.spec, plan.padding
    kt = jnp.flip(k, axis=(-2, -1)).transpose(1, 0, 2, 3)  # (C, C', kh, kw)
    dx_full = pipeline_conv(_transposed_plan(plan), dy, kt)
    return jax.lax.dynamic_slice(
        dx_full, (0, 0, pad[0], pad[1]), (s.B, s.C, s.H, s.W))


def _fwd(plan, x, k):
    return pipeline_conv(plan, x, k), (x, k)


def _bwd(plan, res, dy):
    x, k = res
    pad = plan.padding
    dx = _dx_via_transposed_plan(plan, k, dy)
    # dk: correlation of x with dy, batch as the contraction axis. The
    # "kernel" (dy) spatial extent exceeds the tile, so use the direct path.
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    dk = jax.lax.conv_general_dilated(
        xp.transpose(1, 0, 2, 3),                  # (C, B, Hp, Wp)
        dy.transpose(1, 0, 2, 3),                  # (C', B, Ho, Wo)
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).transpose(1, 0, 2, 3)                        # (C', C, kh, kw)
    return dx.astype(x.dtype), dk.astype(k.dtype)


pipeline_conv.defvjp(_fwd, _bwd)


# --------------------------------------------------------------------------
# Prepared execution: differentiable w.r.t. x on every pipeline backend
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def prepared_conv(prepared, x):
    """Execute a ``PreparedConv`` with grads w.r.t. ``x`` defined by the
    same transposed-plan VJP as ``pipeline_conv`` — which also shields the
    Pallas CGEMM kernel from being differentiated through, so prepared
    ``fft-pallas`` trains its inputs too.  (The kernel is frozen in a
    prepared plan; there is no dk.)"""
    plan = prepared.plan
    pipeline = _pipeline(plan)
    return pipeline.execute(plan, x, prepared.state)


def _prep_fwd(prepared, x):
    return prepared_conv(prepared, x), None


def _prep_bwd(prepared, _res, dy):
    plan = prepared.plan
    dx = _dx_via_transposed_plan(plan, prepared.kernel, dy)
    # execution returns x.dtype, so dy carries the input dtype
    return (dx.astype(dy.dtype),)


prepared_conv.defvjp(_prep_fwd, _prep_bwd)
