"""Plan-level reverse-mode autodiff for the stage-graph conv engine.

Differentiability is a property of the *plan*, not of one backend's
implementation: every backend that executes through a stage pipeline gets
the same custom VJP, defined once here over the whole pipeline —

  dx : a *transposed* plan (same backend, schedule, mesh and precision as
       the forward) applied to the conv-output cotangent and the spatially
       flipped, channel-transposed kernel, "full"-correlation padding,
       cropped by the forward padding;
  dk : direct correlation of x with the conv-output cotangent, batch as
       the contraction axis (dy's spatial extent exceeds the FFT tile, so
       the direct path is the right algorithm — one oracle call).

Fused-epilogue plans train through the same machinery: the forward (under
differentiation) computes the *pre-activation* value ``z`` via a plan
whose epilogue keeps bias/residual fused but drops the activation, the
activation is applied outside, and the backward pass first pulls ``dy``
back through the activation at ``z`` —

  dz       = dy * act'(z)        (the conv-output cotangent)
  d_bias   = sum dz over (B, H, W)
  d_residual = dz
  dx, dk   = the unfused rules above, driven by dz.

Because the backward pass is expressed as plans, it runs through the same
schedules as the forward: the gradient of an ``nfft`` conv is itself an
``nfft`` conv (collectives and all), which is what makes training *through*
the NUMA-aware schedule possible.  The Pallas backend is shielded by the
VJP (its kernels are never differentiated through), so ``fft-pallas``
trains too — including the fused ``dft_tile`` epilogue tail.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.conv.epilogue import ACTIVATIONS, activation_vjp, bias_grad


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def pipeline_conv(plan, x, k, bias=None, residual=None):
    """Differentiable execution of a stage-pipeline plan (epilogue fused)."""
    return _pipeline(plan).full(plan, x, k, bias=bias, residual=residual)


def _pipeline(plan):
    from repro.conv import registry
    return registry.get_backend(plan.backend).make_pipeline(plan)


def _pre_activation_plan(plan):
    """The same plan with the activation dropped from its epilogue (bias
    and residual stay fused): its output is the pre-activation ``z`` the
    backward pass needs."""
    return dataclasses.replace(
        plan, epilogue=dataclasses.replace(plan.epilogue, activation="none"))


def _transposed_plan(plan):
    """The plan computing dx: conv of dy (B, C', Ho, Wo) with the flipped,
    transposed kernel (C, C', kh, kw) at full-correlation padding, on the
    same backend x schedule (and mesh/precision knobs) as the forward.
    No epilogue — cotangents propagate through the raw conv."""
    from repro.conv.plan import plan_conv
    s = plan.spec
    return plan_conv(
        (s.B, s.Cout, s.Ho, s.Wo), (s.C, s.Cout, s.kh, s.kw),
        padding=(s.kh - 1, s.kw - 1), delta=s.delta, backend=plan.backend,
        schedule=plan.schedule, mesh=plan.mesh, three_m=plan.three_m,
        bm=plan.bm, bn=plan.bn, bk=plan.bk,
        compute_dtype=plan.compute_dtype, data_axis=plan.data_axis,
        model_axis=plan.model_axis,
        replicate_kernel_transform=plan.replicate_kernel_transform,
        spectrum=plan.spectrum, overlap=plan.overlap)


def _dx_via_transposed_plan(plan, k, dz):
    """dx: transposed plan on the flipped/channel-transposed kernel; the
    recursive pipeline_conv call keeps higher-order grads working."""
    s, pad = plan.spec, plan.padding
    kt = jnp.flip(k, axis=(-2, -1)).transpose(1, 0, 2, 3)  # (C, C', kh, kw)
    dx_full = pipeline_conv(_transposed_plan(plan), dz, kt, None, None)
    return jax.lax.dynamic_slice(
        dx_full, (0, 0, pad[0], pad[1]), (s.B, s.C, s.H, s.W))


def _dk_direct(plan, x, dz, k_dtype):
    """dk: correlation of x with dz, batch as the contraction axis. The
    "kernel" (dz) spatial extent exceeds the tile, so use the direct path."""
    pad = plan.padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    return jax.lax.conv_general_dilated(
        xp.transpose(1, 0, 2, 3),                  # (C, B, Hp, Wp)
        dz.transpose(1, 0, 2, 3),                  # (C', B, Ho, Wo)
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).transpose(1, 0, 2, 3).astype(k_dtype)        # (C', C, kh, kw)


def _fwd(plan, x, k, bias, residual):
    ep = plan.epilogue
    if ep.activation == "none":
        # no activation: the fused output IS the pre-activation value
        return pipeline_conv(plan, x, k, bias, residual), \
            (x, k, bias, residual, None)
    z = pipeline_conv(_pre_activation_plan(plan), x, k, bias, residual)
    return ACTIVATIONS[ep.activation](z), (x, k, bias, residual, z)


def _bwd(plan, res, dy):
    x, k, bias, residual, z = res
    ep = plan.epilogue
    # activation grad first: the conv-output cotangent dz drives everything
    dz = dy if z is None else activation_vjp(ep, z, dy)
    dx = _dx_via_transposed_plan(plan, k, dz)
    dk = _dk_direct(plan, x, dz, k.dtype)
    dbias = bias_grad(dz).astype(bias.dtype) if ep.bias else None
    dres = dz.astype(residual.dtype) if ep.residual else None
    return dx.astype(x.dtype), dk, dbias, dres


pipeline_conv.defvjp(_fwd, _bwd)


# --------------------------------------------------------------------------
# Prepared execution: differentiable w.r.t. x (and the epilogue operands)
# on every pipeline backend
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def prepared_conv(prepared, x, bias=None, residual=None):
    """Execute a ``PreparedConv`` with grads w.r.t. ``x`` (and bias /
    residual, when the epilogue carries them) defined by the same
    transposed-plan VJP as ``pipeline_conv`` — which also shields the
    Pallas kernels from being differentiated through, so prepared
    ``fft-pallas`` trains its inputs too.  (The conv kernel is frozen in a
    prepared plan; there is no dk.)"""
    plan = prepared.plan
    return _pipeline(plan).execute(plan, x, prepared.state, bias=bias,
                                   residual=residual)


def _prep_fwd(prepared, x, bias, residual):
    ep = prepared.plan.epilogue
    if ep.activation == "none":
        return prepared_conv(prepared, x, bias, residual), \
            (bias, residual, None)
    pre = dataclasses.replace(prepared, plan=_pre_activation_plan(
        prepared.plan))
    z = prepared_conv(pre, x, bias, residual)
    return ACTIVATIONS[ep.activation](z), (bias, residual, z)


def _prep_bwd(prepared, res, dy):
    bias, residual, z = res
    plan = prepared.plan
    ep = plan.epilogue
    dz = dy if z is None else activation_vjp(ep, z, dy)
    dx = _dx_via_transposed_plan(plan, prepared.kernel, dz)
    dbias = bias_grad(dz).astype(bias.dtype) if ep.bias else None
    dres = dz.astype(residual.dtype) if ep.residual else None
    # execution returns x.dtype, so dy carries the input dtype
    return dx.astype(dy.dtype), dbias, dres


prepared_conv.defvjp(_prep_fwd, _prep_bwd)
