"""AOT-exported plan artifacts for fleet cold-start (``repro.conv.export``).

Serving a model on a fresh worker normally re-pays the whole plan
lifecycle per process: plan every layer, transform every kernel, trace
and compile every (layer x bucket) jit.  The paper's pipeline wins by
doing all layout decisions ONCE and amortizing them; this module extends
that amortization across the fleet:

    net = plan_network(layers, ...)
    net.export("vgg.rpa", params=kernels, weights_version=7)   # build once

    # on a fresh worker: zero re-planning, zero re-tracing
    loaded = load_network("vgg.rpa")
    y = loaded["conv1"](x, bias=b)                             # deploy many

An artifact is a single zip file holding, per (net, layer):

  ``manifest.json``    format/jax/device-kind/mesh compatibility stamps,
                       the full resolved plan config (enough to re-plan
                       live), the ``weights_version``, and a plan-lint
                       ``PlanProfile`` fingerprint per layer.
  ``fns/<hash>.bin``   the ``jax.export`` serialized StableHLO module
                       (deduplicated across same-plan layers/buckets).
  ``exe/<hash>.pkl``   the XLA *executable* for that module
                       (``jax.experimental.serialize_executable``) —
                       zero-compile rehydration on an identical worker.
  ``.../state<i>.npy`` the prepared kernel slabs (stage-2 output in the
                       exact layout the schedule consumes).
  ``.../kernel.npy``   the raw kernel, so an incompatible worker can
                       still fall back to live planning.

``load_network`` validates device-kind / jax-version / mesh-shape
compatibility; compatible artifacts rehydrate native executables first
(no tracing, no XLA compile), per-layer falling back to the portable
StableHLO module (no tracing, one compile).  On a compatibility mismatch
it warns and falls back to live planning from the stored configs +
kernels (``on_mismatch="error"`` raises instead).
``verify`` re-derives every fingerprint from a live re-plan and compares
against the export-time stamps — the plan-lint certificate that the
artifact executes the same schedule it was built from.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import io
import json
import os
import pickle
import warnings
import zipfile
from typing import Any, Mapping, Optional

from repro.conv.epilogue import Epilogue

ARTIFACT_VERSION = 1

# The PlanProfile facts a fingerprint certifies: everything structural
# about the schedule (backend/schedule/collectives/stage ops/spectrum/
# overlap/epilogue/precision), nothing measured or byte-counted.
FINGERPRINT_FIELDS = (
    "backend", "schedule", "prepared", "collectives", "stage_counts",
    "spectrum", "overlap", "num_slabs", "epilogue", "compute_dtype",
    "cgemm_dtypes",
)


class ArtifactMismatch(RuntimeError):
    """The artifact cannot be used as-is on this worker."""


# --------------------------------------------------------------------------
# Fingerprints (plan-lint certificate)
# --------------------------------------------------------------------------

def plan_fingerprint(plan, *, prepared: bool = False) -> str:
    """sha256 over the canonical structural subset of the plan's
    ``PlanProfile`` (``FINGERPRINT_FIELDS``).  Stable across processes on
    one jax version, so a fresh worker can certify an artifact by
    re-planning live and comparing."""
    prof = plan.analyze(prepared=prepared).to_dict()
    payload = {k: prof.get(k) for k in FINGERPRINT_FIELDS}
    blob = json.dumps(payload, sort_keys=True, default=str).encode()
    return "sha256:" + hashlib.sha256(blob).hexdigest()


# --------------------------------------------------------------------------
# Plan config (de)serialization — enough to re-plan live
# --------------------------------------------------------------------------

def _dtype_name(dt) -> Optional[str]:
    if dt is None:
        return None
    import numpy as np
    return np.dtype(dt).name


def _mesh_config(mesh) -> Optional[dict]:
    if mesh is None:
        return None
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(s) for s in mesh.devices.shape]}


def _rebuild_mesh(cfg: Optional[dict]):
    if cfg is None:
        return None
    import jax
    from repro.compat import make_mesh
    need = 1
    for s in cfg["shape"]:
        need *= int(s)
    if need > len(jax.devices()):
        raise ArtifactMismatch(
            f"artifact mesh {tuple(cfg['shape'])} needs {need} devices, "
            f"this worker has {len(jax.devices())}")
    return make_mesh(tuple(int(s) for s in cfg["shape"]),
                     tuple(cfg["axis_names"]))


def plan_config(plan) -> dict:
    """JSON-able resolved plan config; ``rebuild_plan`` inverts it."""
    return {
        "x_shape": list(plan.x_shape),
        "k_shape": list(plan.k_shape),
        "padding": list(plan.padding),
        "delta": int(plan.spec.delta),
        "backend": plan.backend,
        "schedule": plan.schedule,
        "three_m": bool(plan.three_m),
        "bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
        "dft_bt": plan.dft_bt,
        "compute_dtype": _dtype_name(plan.compute_dtype),
        "mesh": _mesh_config(plan.mesh),
        "data_axis": plan.data_axis,
        "model_axis": plan.model_axis,
        "replicate_kernel_transform": bool(plan.replicate_kernel_transform),
        "epilogue": {"bias": plan.epilogue.bias,
                     "activation": plan.epilogue.activation,
                     "residual": plan.epilogue.residual},
        "spectrum": plan.spectrum,
        "overlap": plan.overlap,
    }


def rebuild_plan(cfg: dict):
    """Re-plan live from a stored config (the fallback path).  Raises
    ``ArtifactMismatch`` when the mesh cannot be rebuilt here."""
    import numpy as np
    from repro.conv.plan import plan_conv
    mesh = _rebuild_mesh(cfg.get("mesh"))
    cd = cfg.get("compute_dtype")
    return plan_conv(
        tuple(cfg["x_shape"]), tuple(cfg["k_shape"]),
        padding=tuple(cfg["padding"]), delta=int(cfg["delta"]),
        backend=cfg["backend"], schedule=cfg["schedule"], mesh=mesh,
        three_m=cfg["three_m"], bm=cfg["bm"], bn=cfg["bn"], bk=cfg["bk"],
        dft_bt=cfg["dft_bt"],
        compute_dtype=None if cd is None else np.dtype(cd),
        data_axis=cfg["data_axis"], model_axis=cfg["model_axis"],
        replicate_kernel_transform=cfg["replicate_kernel_transform"],
        epilogue=Epilogue(**cfg["epilogue"]),
        spectrum=cfg["spectrum"], overlap=cfg["overlap"])


# --------------------------------------------------------------------------
# The exported callable per layer
# --------------------------------------------------------------------------

def _layer_fn(plan, *, prepared: bool, treedef, n_state: int):
    """The function ``jax.export`` lowers for one layer.

    Prepared: ``fn(x, *state_leaves, [bias], [residual])`` — stages
    1/3/4 against the baked slab layout.  Unprepared:
    ``fn(x, k, [bias], [residual])`` — the full pipeline.  Epilogue
    operands stay runtime arguments so an artifact serves any bias/
    residual values without re-export."""
    import jax
    from repro.conv import registry
    be = registry.get_backend(plan.backend)
    ep = plan.epilogue

    def fn(x, *args):
        state = jax.tree_util.tree_unflatten(treedef, list(args[:n_state]))
        ops = args[n_state:]
        bias = residual = None
        i = 0
        if ep.bias:
            bias = ops[i]
            i += 1
        if ep.residual:
            residual = ops[i]
        if be.pipeline_factory is not None:
            pipe = be.make_pipeline(plan)
            if prepared:
                return pipe.execute(plan, x, state, bias=bias,
                                    residual=residual)
            return pipe.full(plan, x, state, bias=bias, residual=residual)
        if not ep.is_noop:
            return be.execute(plan, x, state, bias=bias, residual=residual)
        return be.execute(plan, x, state)

    return fn


def _np_bytes(arr) -> bytes:
    import numpy as np
    bio = io.BytesIO()
    np.save(bio, np.asarray(arr))
    return bio.getvalue()


def _np_load(data: bytes):
    import numpy as np
    return np.load(io.BytesIO(data))


def _state_format(treedef, leaves) -> str:
    import jax
    if treedef == jax.tree_util.tree_structure(leaves[0]) \
            and len(leaves) == 1:
        return "leaf"
    if treedef == jax.tree_util.tree_structure(tuple(leaves)):
        return "tuple"
    raise ValueError(
        f"unsupported prepared-state structure {treedef} (export knows "
        "flat tuples and single leaves)")


def _state_treedef(fmt: str, n: int):
    import jax
    if fmt == "leaf":
        return jax.tree_util.tree_structure(0)
    return jax.tree_util.tree_structure(tuple(range(n)))


# --------------------------------------------------------------------------
# Export
# --------------------------------------------------------------------------

def _as_net_mapping(net) -> "collections.OrderedDict":
    """Normalize NetworkPlan | BucketedNetworkPlan | Mapping[label,
    NetworkPlan] to an ordered label -> NetworkPlan mapping."""
    from repro.conv.netplan import BucketedNetworkPlan, NetworkPlan
    if isinstance(net, NetworkPlan):
        return collections.OrderedDict([("net", net)])
    if isinstance(net, BucketedNetworkPlan):
        return collections.OrderedDict(
            (f"b{b}", n) for b, n in net.items())
    return collections.OrderedDict(
        (str(label), n) for label, n in net.items())


def export_network(net, path: str, *, params: Optional[Mapping] = None,
                   weights_version=None, dtype=None) -> str:
    """Lower every (layer x net) jit through ``jax.export`` into one
    artifact file.  With ``params`` the layers export *prepared* (the
    transformed kernel slabs ride along, version-keyed); without, the
    artifact is unprepared and loaded layers take ``(x, k)``.  Returns
    ``path``."""
    import jax
    import jax.numpy as jnp
    from jax import export as jax_export
    nets = _as_net_mapping(net)
    prepared = params is not None
    dt = jnp.float32 if dtype is None else dtype
    uses_mesh = any(p.mesh is not None
                    for n in nets.values() for p in n.plans.values())
    manifest: dict = {
        "artifact_version": ARTIFACT_VERSION,
        "jax_version": jax.__version__,
        "device_kind": jax.devices()[0].device_kind,
        "platform": jax.default_backend(),
        "nr_devices": len(jax.devices()),
        "uses_mesh": uses_mesh,
        "weights_version": weights_version,
        "prepared": prepared,
        "dtype": _dtype_name(dt),
        "nets": {},
    }
    fn_members: dict = {}            # (id(plan), prepared) -> member name
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with zipfile.ZipFile(tmp, "w", zipfile.ZIP_DEFLATED) as zf:
        for label, nplan in nets.items():
            layers: dict = {}
            for name, plan in nplan.items():
                layers[name] = _export_layer(
                    zf, f"nets/{label}/{name}", plan, name, params,
                    weights_version=weights_version, dt=dt,
                    fn_members=fn_members, jax_export=jax_export)
            manifest["nets"][label] = {"layers": layers}
        zf.writestr("manifest.json",
                    json.dumps(manifest, indent=1, sort_keys=True))
    os.replace(tmp, path)
    return path


def _export_layer(zf, member_dir, plan, name, params, *, weights_version,
                  dt, fn_members, jax_export) -> dict:
    import jax
    prepared = params is not None
    entry = dict(plan_config(plan))
    entry["fingerprint"] = plan_fingerprint(plan, prepared=prepared)
    entry["prepared"] = prepared
    entry["state"] = []
    entry["kernel"] = None
    if prepared:
        if name not in params:
            raise ValueError(f"export: params missing kernel for {name!r}")
        pc = plan.prepare(params[name], weights_version=weights_version)
        leaves, treedef = jax.tree_util.tree_flatten(pc.state)
        entry["state_format"] = _state_format(treedef, leaves)
        for i, leaf in enumerate(leaves):
            member = f"{member_dir}/state{i}.npy"
            zf.writestr(member, _np_bytes(leaf))
            entry["state"].append(member)
        kmember = f"{member_dir}/kernel.npy"
        zf.writestr(kmember, _np_bytes(params[name]))
        entry["kernel"] = kmember
        state_avals = [jax.ShapeDtypeStruct(tuple(v.shape), v.dtype)
                       for v in leaves]
        n_state = len(leaves)
    else:
        treedef = jax.tree_util.tree_structure(0)
        entry["state_format"] = "leaf"
        state_avals = [jax.ShapeDtypeStruct(plan.k_shape, dt)]
        n_state = 1
    fn_key = (id(plan), prepared)
    if fn_key not in fn_members:
        fn = _layer_fn(plan, prepared=prepared, treedef=treedef,
                       n_state=n_state)
        avals = [jax.ShapeDtypeStruct(plan.x_shape, dt)] + state_avals
        if plan.epilogue.bias:
            avals.append(jax.ShapeDtypeStruct((plan.spec.Cout,), dt))
        if plan.epilogue.residual:
            avals.append(jax.ShapeDtypeStruct(plan.out_shape, dt))
        blob = jax_export.export(jax.jit(fn))(*avals).serialize()
        member = ("fns/"
                  + hashlib.sha256(blob).hexdigest()[:24] + ".bin")
        if member not in {m["fn"] for m in fn_members.values()}:
            zf.writestr(member, bytes(blob))
        fn_members[fn_key] = {"fn": member,
                              "exe": _export_exe(zf, fn, avals, member)}
    entry["fn"] = fn_members[fn_key]["fn"]
    entry["exe"] = fn_members[fn_key]["exe"]
    return entry


def _export_exe(zf, fn, avals, fn_member) -> Optional[str]:
    """Serialize the fully compiled XLA executable next to the portable
    module (best-effort: ``None`` when the backend cannot serialize
    executables).  The exe is device-kind/device-count specific — exactly
    the compatibility the manifest already gates on."""
    import jax
    try:
        from jax.experimental import serialize_executable as se
        compiled = jax.jit(fn).lower(*avals).compile()
        payload, in_tree, out_tree = se.serialize(compiled)
        blob = pickle.dumps((payload, in_tree, out_tree))
    except Exception:
        return None
    member = "exe/" + fn_member[len("fns/"):-len(".bin")] + ".pkl"
    if member not in zf.namelist():
        zf.writestr(member, blob)
    return member


# --------------------------------------------------------------------------
# Load
# --------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class LoadedConv:
    """One rehydrated layer: the deserialized AOT module plus its baked
    slabs, callable with the same convention as ``PreparedConv``
    (prepared: ``layer(x, bias=..., residual=...)``) or ``ConvPlan``
    (unprepared: ``layer(x, k, bias=...)``).  ``native`` means the call
    dispatches a deserialized XLA executable directly — zero compile,
    but eager-only (a ``Compiled`` cannot be traced through an outer
    ``jit``); non-native layers wrap the portable StableHLO module in
    ``jit`` and compose freely."""
    name: str
    config: dict
    fingerprint: str
    prepared: bool
    epilogue: Epilogue
    state: tuple
    _call: Any
    native: bool = False

    @property
    def x_shape(self) -> tuple:
        return tuple(self.config["x_shape"])

    @property
    def k_shape(self) -> tuple:
        return tuple(self.config["k_shape"])

    def __call__(self, x, *args, bias=None, residual=None):
        ep = self.epilogue
        if self.prepared:
            if args:
                raise TypeError(
                    f"prepared loaded layer {self.name!r} takes only x "
                    "(the kernel is baked into the artifact)")
            ops = []
        else:
            if len(args) != 1:
                raise TypeError(
                    f"unprepared loaded layer {self.name!r} takes (x, k)")
            ops = [args[0]]
        if ep.bias != (bias is not None):
            raise ValueError(
                f"layer {self.name!r} epilogue declares bias={ep.bias} "
                f"but bias {'was not' if ep.bias else 'was'} passed")
        if ep.residual != (residual is not None):
            raise ValueError(
                f"layer {self.name!r} epilogue declares residual="
                f"{ep.residual} but residual "
                f"{'was not' if ep.residual else 'was'} passed")
        if bias is not None:
            ops.append(bias)
        if residual is not None:
            ops.append(residual)
        return self._call(x, *ops)


@dataclasses.dataclass(frozen=True, eq=False)
class LoadedNetwork:
    """A rehydrated network: Mapping-like over loaded layers, duck-typed
    to ``PreparedNetwork``.  ``source`` is ``"aot"`` (zero-retrace AOT
    modules) or ``"live"`` (the fallback re-planned this artifact)."""
    layers: "collections.OrderedDict"
    weights_version: Any
    source: str
    fingerprints: dict

    def __getitem__(self, name):
        return self.layers[name]

    def __iter__(self):
        return iter(self.layers)

    def __len__(self):
        return len(self.layers)

    def items(self):
        return self.layers.items()

    @property
    def x_shape(self) -> tuple:
        first = next(iter(self.layers.values()))
        if hasattr(first, "x_shape"):
            return tuple(first.x_shape)
        return tuple(first.plan.x_shape)


def read_manifest(path: str) -> dict:
    with zipfile.ZipFile(path) as zf:
        return json.loads(zf.read("manifest.json"))


def compat_reasons(manifest: dict) -> list:
    """Why this artifact cannot run AOT on this worker ([] = compatible):
    format version, jax version, device kind, and — for sharded plans —
    the device count the meshes were laid out for."""
    import jax
    reasons = []
    if manifest.get("artifact_version") != ARTIFACT_VERSION:
        reasons.append(
            f"artifact format v{manifest.get('artifact_version')} != "
            f"v{ARTIFACT_VERSION}")
    if manifest.get("jax_version") != jax.__version__:
        reasons.append(f"jax {manifest.get('jax_version')} != "
                       f"{jax.__version__}")
    kind = jax.devices()[0].device_kind
    if manifest.get("device_kind") != kind:
        reasons.append(f"device kind {manifest.get('device_kind')!r} != "
                       f"{kind!r}")
    if manifest.get("uses_mesh") and \
            manifest.get("nr_devices") != len(jax.devices()):
        reasons.append(f"mesh laid out for {manifest.get('nr_devices')} "
                       f"devices, worker has {len(jax.devices())}")
    return reasons


def _aot_call(exported, state):
    import jax

    def run(x, *ops):
        return exported.call(x, *state, *ops)

    return jax.jit(run)


def _load_exe(zf, member, cache):
    """Deserialize a native executable member (memoized per load); None
    when the blob does not rehydrate on this worker."""
    if member not in cache:
        try:
            from jax.experimental import serialize_executable as se
            payload, in_tree, out_tree = pickle.loads(zf.read(member))
            cache[member] = se.deserialize_and_load(
                payload, in_tree, out_tree)
        except Exception:
            cache[member] = None
    return cache[member]


def _load_layer_aot(zf, name, entry, exe_cache) -> LoadedConv:
    import jax.numpy as jnp
    from jax import export as jax_export
    state = tuple(jnp.asarray(_np_load(zf.read(m)))
                  for m in entry["state"])
    loaded = _load_exe(zf, entry["exe"], exe_cache) \
        if entry.get("exe") else None
    if loaded is not None:
        def call(x, *ops, _exe=loaded, _state=state):
            return _exe(x, *_state, *ops)
        native = True
    else:
        exported = jax_export.deserialize(bytearray(zf.read(entry["fn"])))
        call = _aot_call(exported, state)
        native = False
    return LoadedConv(
        name=name, config=entry, fingerprint=entry["fingerprint"],
        prepared=entry["prepared"], epilogue=Epilogue(**entry["epilogue"]),
        state=state, _call=call, native=native)


def _load_layer_live(zf, name, entry, weights_version):
    import jax.numpy as jnp
    plan = rebuild_plan(entry)
    if entry["prepared"]:
        k = jnp.asarray(_np_load(zf.read(entry["kernel"])))
        return plan.prepare(k, weights_version=weights_version)
    return plan


def load_network(path: str, *, on_mismatch: str = "fallback"):
    """Rehydrate an artifact on this worker.

    Compatible artifacts load as AOT modules — zero re-planning, zero
    re-tracing, zero kernel re-transforms.  Incompatible ones (other jax
    version / device kind / device count) fall back to live planning
    from the stored configs + kernels with a warning
    (``on_mismatch="error"`` raises ``ArtifactMismatch`` instead).

    Returns a ``LoadedNetwork`` for single-net artifacts, else an
    ``OrderedDict[label, LoadedNetwork]`` (bucketed exports)."""
    if on_mismatch not in ("fallback", "error"):
        raise ValueError(f"unknown on_mismatch {on_mismatch!r}")
    manifest = read_manifest(path)
    reasons = compat_reasons(manifest)
    if reasons:
        if on_mismatch == "error":
            raise ArtifactMismatch(
                f"plan artifact {path!r} incompatible: "
                + "; ".join(reasons))
        warnings.warn(
            f"plan artifact {path!r} incompatible ({'; '.join(reasons)}); "
            "falling back to live planning", stacklevel=2)
    source = "live" if reasons else "aot"
    wv = manifest.get("weights_version")
    out: "collections.OrderedDict" = collections.OrderedDict()
    exe_cache: dict = {}
    with zipfile.ZipFile(path) as zf:
        for label, ncfg in manifest["nets"].items():
            layers: "collections.OrderedDict" = collections.OrderedDict()
            fps = {}
            for name, entry in ncfg["layers"].items():
                fps[name] = entry["fingerprint"]
                if source == "aot":
                    layers[name] = _load_layer_aot(zf, name, entry,
                                                   exe_cache)
                else:
                    layers[name] = _load_layer_live(zf, name, entry, wv)
            out[label] = LoadedNetwork(layers=layers, weights_version=wv,
                                       source=source, fingerprints=fps)
    if list(out) == ["net"]:
        return out["net"]
    return out


def verify(path: str) -> dict:
    """Plan-lint certificate: re-plan every stored layer config LIVE on
    this worker, recompute its ``PlanProfile`` fingerprint, and compare
    against the export-time stamp.  Returns ``{"ok": bool, "n_checked":
    int, "mismatches": [...]}``.  (Re-planning hits the plan cache /
    static analyzer only — nothing executes.)"""
    manifest = read_manifest(path)
    mismatches = []
    n = 0
    for label, ncfg in manifest["nets"].items():
        for name, entry in ncfg["layers"].items():
            n += 1
            plan = rebuild_plan(entry)
            fp = plan_fingerprint(plan, prepared=entry["prepared"])
            if fp != entry["fingerprint"]:
                mismatches.append(
                    {"net": label, "layer": name,
                     "exported": entry["fingerprint"], "live": fp})
    return {"ok": not mismatches, "n_checked": n,
            "mismatches": mismatches}
