"""jit'd public wrapper for the Pallas batched complex GEMM.

Pads (M, N, C) up to block multiples, invokes the kernel, slices back.
On the CPU backend the kernel body runs in interpret mode (Python emulation)
— TPU is the target, CPU validates correctness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cgemm.kernel import cgemm_pallas_call


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# Candidate block edges: power-of-two steps up to the 128-wide MXU/lane
# width.  Small dims round UP to the next edge (operands are zero-padded to
# block multiples) instead of taking the raw dim — a C=3 layer (VGG
# conv1.1) gets an 8-wide block, not a degenerate 3-wide one.
_BLOCK_EDGES = (8, 16, 32, 64, 128)


def _round_block(dim):
    for edge in _BLOCK_EDGES:
        if edge >= dim:
            return edge
    return _BLOCK_EDGES[-1]


def _default_blocks(M, N, C):
    # MXU-aligned when the problem allows; lane-friendly for small operands.
    return _round_block(M), _round_block(N), _round_block(C)


def default_blocks(M, N, C):
    """Heuristic (bm, bn, bk) for a (P, M, C) x (P, C, N) CGEMM — the
    blocks used when no explicit override is given (autotune candidate
    generation seeds its block search from this)."""
    return _default_blocks(M, N, C)


_LANE = 8                                 # sublane-friendly block alignment


def _shrink_block(dim, block):
    """Shrink a heuristic default block to fit ``dim`` with at most one
    lane-alignment's padding, keeping the grid-step count the full-size
    block would need.  A 100-wide dim under a 128 default becomes 104
    (one 8-aligned step) instead of zero-padding 28 ghost columns; odd
    half-spectrum slabs (e.g. P_real=130 rows of M) stop re-padding at
    every stage that touches them."""
    steps = max(1, -(-dim // block))
    fitted = -(-dim // steps)             # ceil: balanced across steps
    fitted = -(-fitted // _LANE) * _LANE  # align up to the lane width
    return min(block, fitted)


def resolve_blocks(M, N, C, bm=None, bn=None, bk=None, slabs: int = 1):
    """Merge explicit block overrides over the heuristic defaults.

    ``None`` means "use the default", shrunk to fit the dim (see
    ``_shrink_block`` — padding is applied once, not per stage); explicit
    values are honored verbatim and must be positive ints (operands are
    zero-padded up to block multiples, so any positive edge is legal —
    the autotuner decides what's *fast*).

    ``slabs > 1`` resolves for comm/compute-overlapped execution where the
    M axis is subdivided into that many batch sub-slabs: the default bm is
    shrunk against the *smallest* sub-slab's rows, so ONE block config
    (clamped once at plan time) covers every slab — per-slab re-resolution
    would pick a bigger block for the larger slabs and re-pad the smaller
    ones on every call.
    """
    if isinstance(slabs, bool) or not isinstance(slabs, int) or slabs < 1:
        raise ValueError(f"slabs must be a positive int, got {slabs!r}")
    m_fit = max(1, M // slabs)            # smallest sub-slab's row count
    resolved = []
    for name, v, dim, d in zip(("bm", "bn", "bk"), (bm, bn, bk),
                               (m_fit, N, C), _default_blocks(m_fit, N, C)):
        if v is None:
            v = _shrink_block(dim, d)
        if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
            raise ValueError(
                f"cgemm block override {name} must be a positive int or "
                f"None, got {v!r}")
        resolved.append(v)
    return tuple(resolved)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "three_m",
                                             "interpret"))
def cgemm_pallas(Dr, Di, Gr, Gi, *, bm=None, bn=None, bk=None,
                 three_m: bool = True, interpret: bool | None = None):
    """Batched complex GEMM: (P,M,C) x (P,C,N) -> (P,M,N) (real, imag)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    P, M, C = Dr.shape
    N = Gr.shape[-1]
    bm, bn, bk = resolve_blocks(M, N, C, bm, bn, bk)
    Drp = _pad_to(_pad_to(Dr, 1, bm), 2, bk)
    Dip = _pad_to(_pad_to(Di, 1, bm), 2, bk)
    Grp = _pad_to(_pad_to(Gr, 1, bk), 2, bn)
    Gip = _pad_to(_pad_to(Gi, 1, bk), 2, bn)
    call = cgemm_pallas_call(P, Drp.shape[1], Grp.shape[2], Drp.shape[2],
                             Dr.dtype, bm=bm, bn=bn, bk=bk,
                             three_m=three_m, interpret=interpret)
    Zr, Zi = call(Drp, Dip, Grp, Gip)
    return Zr[:, :M, :N], Zi[:, :M, :N]
