"""Pallas TPU kernel: P-batched complex GEMM (the paper's hot stage).

Z[p] = D[p] @ G[p], complex held as separate real/imag planes.

Grid: (P, M/bm, N/bn, C/bk); the contraction dimension kk is innermost so the
output block stays resident in VMEM across the K loop (accumulator pattern).
This is the TPU analogue of the paper's three-level parallelisation:

  node-level   -> grid dim p (frequency points; sharded over the mesh by
                  repro.parallel.nfft so each chip sees a contiguous P/N slab)
  core-level   -> grid dims (i, j) tiling M x N per chip
  vector-level -> the MXU contraction itself (128x128 systolic)

Block sizes default to MXU-aligned (128) and are clamped/padded by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cgemm_kernel(dr_ref, di_ref, gr_ref, gi_ref, zr_ref, zi_ref,
                  *, three_m: bool):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        zr_ref[...] = jnp.zeros_like(zr_ref)
        zi_ref[...] = jnp.zeros_like(zi_ref)

    dr = dr_ref[0]          # (bm, bk)
    di = di_ref[0]
    gr = gr_ref[0]          # (bk, bn)
    gi = gi_ref[0]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    if three_m:
        t1 = dot(dr, gr)
        t2 = dot(di, gi)
        t3 = dot(dr + di, gr + gi)
        zr, zi = t1 - t2, t3 - t1 - t2
    else:
        zr = dot(dr, gr) - dot(di, gi)
        zi = dot(dr, gi) + dot(di, gr)
    zr_ref[0] += zr.astype(zr_ref.dtype)
    zi_ref[0] += zi.astype(zi_ref.dtype)


def cgemm_pallas_call(P: int, M: int, N: int, C: int, dtype,
                      *, bm: int, bn: int, bk: int,
                      three_m: bool = True, interpret: bool = False):
    """Build the pallas_call for pre-padded operands (bm|M, bn|N, bk|C)."""
    assert M % bm == 0 and N % bn == 0 and C % bk == 0
    grid = (P, M // bm, N // bn, C // bk)
    d_spec = pl.BlockSpec((1, bm, bk), lambda p, i, j, k: (p, i, k))
    g_spec = pl.BlockSpec((1, bk, bn), lambda p, i, j, k: (p, k, j))
    z_spec = pl.BlockSpec((1, bm, bn), lambda p, i, j, k: (p, i, j))
    out_shape = [jax.ShapeDtypeStruct((P, M, N), dtype)] * 2
    kernel = functools.partial(_cgemm_kernel, three_m=three_m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[d_spec, d_spec, g_spec, g_spec],
        out_specs=[z_spec, z_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
