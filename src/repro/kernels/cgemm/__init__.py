from repro.kernels.cgemm.ops import (
    cgemm_pallas, default_blocks, resolve_blocks,
)
from repro.kernels.cgemm.ref import cgemm_ref

__all__ = ["cgemm_pallas", "cgemm_ref", "default_blocks", "resolve_blocks"]
