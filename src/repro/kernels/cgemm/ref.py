"""Pure-jnp oracle for the batched complex GEMM kernel."""
import jax
import jax.numpy as jnp


def cgemm_ref(Dr, Di, Gr, Gi):
    """Z[p] = D[p] @ G[p]; (P,M,C) x (P,C,N) -> (P,M,N) real/imag pair."""
    ein = lambda a, b: jnp.einsum("pmc,pcn->pmn", a, b,
                                  precision=jax.lax.Precision.HIGHEST)
    return ein(Dr, Gr) - ein(Di, Gi), ein(Dr, Gi) + ein(Di, Gr)
