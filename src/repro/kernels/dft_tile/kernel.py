"""Pallas TPU kernel: fused 2-D DFT of a block of tiles (stages 1/2/4).

Replaces NEON FFT butterflies with MXU matmuls: for each 16x16 tile x,
  forward:  T = (F @ x) @ F_half^T        (real input -> complex output)
  inverse:  y = Re((Finv @ Z) @ W^T)      (complex input -> real output)

A block of ``bt`` tiles is processed per grid step; both matmul stages happen
in VMEM, so the intermediate (F @ x) never touches HBM — that is the fusion
the kernel buys over the unfused einsum path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, fr_ref, fi_ref, fhr_ref, fhi_ref, tr_ref, ti_ref):
    x = x_ref[...]                       # (bt, d, d) real
    fr, fi = fr_ref[...], fi_ref[...]    # (d, d)
    fhr, fhi = fhr_ref[...], fhi_ref[...]  # (dh, d)
    # A = F @ x per tile: contract F's h with x's h (axis 1 of tile).
    ar = jnp.einsum("uh,nhw->nuw", fr, x, preferred_element_type=jnp.float32)
    ai = jnp.einsum("uh,nhw->nuw", fi, x, preferred_element_type=jnp.float32)
    # T = A @ F_half^T
    tr = jnp.einsum("nuw,vw->nuv", ar, fhr,
                    preferred_element_type=jnp.float32) \
        - jnp.einsum("nuw,vw->nuv", ai, fhi,
                     preferred_element_type=jnp.float32)
    ti = jnp.einsum("nuw,vw->nuv", ar, fhi,
                    preferred_element_type=jnp.float32) \
        + jnp.einsum("nuw,vw->nuv", ai, fhr,
                     preferred_element_type=jnp.float32)
    tr_ref[...] = tr.astype(tr_ref.dtype)
    ti_ref[...] = ti.astype(ti_ref.dtype)


def _rfwd_kernel(x_ref, fr_ref, fi_ref, fhr_ref, fhi_ref, store_ref,
                 tr_ref, ti_ref):
    """Forward tile DFT + compact-Hermitian gather in one VMEM pass.

    The rect rfft2 result (bt, d, dh) never reaches HBM: the kernel gathers
    the ``store`` frequency list (see ``repro.core.dft.compact_layout``)
    while the block is VMEM-resident, emitting (bt, P) flat planes.
    """
    x = x_ref[...]                       # (bt, d, d) real
    fr, fi = fr_ref[...], fi_ref[...]
    fhr, fhi = fhr_ref[...], fhi_ref[...]
    store = store_ref[...][0]            # (1, P) -> (P,)
    ar = jnp.einsum("uh,nhw->nuw", fr, x, preferred_element_type=jnp.float32)
    ai = jnp.einsum("uh,nhw->nuw", fi, x, preferred_element_type=jnp.float32)
    tr = jnp.einsum("nuw,vw->nuv", ar, fhr,
                    preferred_element_type=jnp.float32) \
        - jnp.einsum("nuw,vw->nuv", ai, fhi,
                     preferred_element_type=jnp.float32)
    ti = jnp.einsum("nuw,vw->nuv", ar, fhi,
                    preferred_element_type=jnp.float32) \
        + jnp.einsum("nuw,vw->nuv", ai, fhr,
                     preferred_element_type=jnp.float32)
    bt = tr.shape[0]
    tr_ref[...] = jnp.take(tr.reshape(bt, -1), store,
                           axis=1).astype(tr_ref.dtype)
    ti_ref[...] = jnp.take(ti.reshape(bt, -1), store,
                           axis=1).astype(ti_ref.dtype)


def _scatter_to_rect(zr, zi, src, sgn, delta):
    """Compact flat planes (bt, P) -> rect (bt, d, dh) via the conj-mirror
    scatter: dropped points read their mirror with the imag plane negated."""
    bt, dh = zr.shape[0], delta // 2 + 1
    zr_rect = jnp.take(zr, src, axis=1).reshape(bt, delta, dh)
    zi_rect = (jnp.take(zi, src, axis=1)
               * sgn.astype(zi.dtype)).reshape(bt, delta, dh)
    return zr_rect, zi_rect


def _rinv_kernel(zr_ref, zi_ref, fvr_ref, fvi_ref, wr_ref, wi_ref,
                 src_ref, sgn_ref, y_ref, *, delta):
    zr, zi = _scatter_to_rect(zr_ref[...], zi_ref[...], src_ref[...][0],
                              sgn_ref[...][0], delta)
    y = _inverse_block(zr, zi, fvr_ref[...], fvi_ref[...],
                       wr_ref[...], wi_ref[...])
    y_ref[...] = y.astype(y_ref.dtype)


def _rinv_epilogue_kernel(zr_ref, zi_ref, fvr_ref, fvi_ref, wr_ref, wi_ref,
                          src_ref, sgn_ref, b_ref, y_ref, *, delta,
                          activation):
    """Compact-layout scatter + inverse tile DFT + bias/activation tail,
    all on the VMEM-resident block (the ``spectrum="real"`` stage-4 fast
    path)."""
    zr, zi = _scatter_to_rect(zr_ref[...], zi_ref[...], src_ref[...][0],
                              sgn_ref[...][0], delta)
    y = _inverse_block(zr, zi, fvr_ref[...], fvi_ref[...],
                       wr_ref[...], wi_ref[...])
    y = y + b_ref[...][:, :, None]
    y = _TAIL_ACTIVATIONS[activation](y)
    y_ref[...] = y.astype(y_ref.dtype)


def _inverse_block(zr, zi, fvr, fvi, wr, wi):
    """The shared inverse-DFT math: Z (bt, d, dh) -> y (bt, d, d) real.
    ``_inv_kernel`` and ``_inv_epilogue_kernel`` differ only in the tail
    they apply to this block's result."""
    yr = jnp.einsum("hu,nuv->nhv", fvr, zr,
                    preferred_element_type=jnp.float32) \
        - jnp.einsum("hu,nuv->nhv", fvi, zi,
                     preferred_element_type=jnp.float32)
    yi = jnp.einsum("hu,nuv->nhv", fvr, zi,
                    preferred_element_type=jnp.float32) \
        + jnp.einsum("hu,nuv->nhv", fvi, zr,
                     preferred_element_type=jnp.float32)
    return jnp.einsum("nhv,wv->nhw", yr, wr,
                      preferred_element_type=jnp.float32) \
        - jnp.einsum("nhv,wv->nhw", yi, wi,
                     preferred_element_type=jnp.float32)


def _inv_kernel(zr_ref, zi_ref, fvr_ref, fvi_ref, wr_ref, wi_ref, y_ref):
    y = _inverse_block(zr_ref[...], zi_ref[...], fvr_ref[...], fvi_ref[...],
                       wr_ref[...], wi_ref[...])
    y_ref[...] = y.astype(y_ref.dtype)


# Epilogue activations implementable in the kernel tail (VPU-only ops; the
# tanh-approximate gelu matches repro.conv.epilogue.ACTIVATIONS exactly).
_TAIL_ACTIVATIONS = {
    "none": lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": lambda y: jax.nn.gelu(y, approximate=True),
    "silu": jax.nn.silu,
}


def _inv_epilogue_kernel(zr_ref, zi_ref, fvr_ref, fvi_ref, wr_ref, wi_ref,
                         b_ref, y_ref, *, activation):
    """Inverse tile DFT with the conv epilogue fused into the tail.

    The second matmul's result never round-trips to HBM before the
    bias/activation pass — the whole epilogue happens on the VMEM-resident
    block, which is the memory-traffic saving the fusion buys (the inverse
    transform is bandwidth-bound, per Zlateski et al.).
    ``b_ref`` holds one bias scalar per tile (the tile's output channel).
    """
    y = _inverse_block(zr_ref[...], zi_ref[...], fvr_ref[...], fvi_ref[...],
                       wr_ref[...], wi_ref[...])
    y = y + b_ref[...][:, :, None]             # (bt, 1) -> per-tile scalar
    y = _TAIL_ACTIVATIONS[activation](y)
    y_ref[...] = y.astype(y_ref.dtype)


def _mat_spec(shape):
    return pl.BlockSpec(shape, lambda i: (0,) * len(shape))


def tile_fft_call(n: int, delta: int, dtype, *, bt: int,
                  interpret: bool = False):
    """Forward tile DFT over (n, delta, delta) -> 2x (n, delta, dh)."""
    assert n % bt == 0
    dh = delta // 2 + 1
    x_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    t_spec = pl.BlockSpec((bt, delta, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _fwd_kernel,
        grid=(n // bt,),
        in_specs=[x_spec, _mat_spec((delta, delta)), _mat_spec((delta, delta)),
                  _mat_spec((dh, delta)), _mat_spec((dh, delta))],
        out_specs=[t_spec, t_spec],
        out_shape=[jax.ShapeDtypeStruct((n, delta, dh), dtype)] * 2,
        interpret=interpret,
    )


def tile_ifft_call(n: int, delta: int, dtype, *, bt: int,
                   interpret: bool = False):
    """Inverse tile DFT over 2x (n, delta, dh) -> (n, delta, delta) real."""
    assert n % bt == 0
    dh = delta // 2 + 1
    z_spec = pl.BlockSpec((bt, delta, dh), lambda i: (i, 0, 0))
    y_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _inv_kernel,
        grid=(n // bt,),
        in_specs=[z_spec, z_spec, _mat_spec((delta, delta)),
                  _mat_spec((delta, delta)), _mat_spec((delta, dh)),
                  _mat_spec((delta, dh))],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((n, delta, delta), dtype),
        interpret=interpret,
    )


def tile_rfft_call(n: int, delta: int, P: int, dtype, *, bt: int,
                   interpret: bool = False):
    """Forward tile DFT + compact gather: (n, delta, delta) -> 2x (n, P)."""
    assert n % bt == 0
    dh = delta // 2 + 1
    x_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    t_spec = pl.BlockSpec((bt, P), lambda i: (i, 0))
    return pl.pallas_call(
        _rfwd_kernel,
        grid=(n // bt,),
        in_specs=[x_spec, _mat_spec((delta, delta)), _mat_spec((delta, delta)),
                  _mat_spec((dh, delta)), _mat_spec((dh, delta)),
                  _mat_spec((1, P))],
        out_specs=[t_spec, t_spec],
        out_shape=[jax.ShapeDtypeStruct((n, P), dtype)] * 2,
        interpret=interpret,
    )


def tile_irfft_call(n: int, delta: int, P: int, dtype, *, bt: int,
                    interpret: bool = False):
    """Compact-layout inverse tile DFT: 2x (n, P) -> (n, delta, delta).

    ``P`` may exceed the layout's true point count (all-to-all padding);
    every scatter index points below it, so trailing rows are ignored.
    """
    assert n % bt == 0
    dh = delta // 2 + 1
    z_spec = pl.BlockSpec((bt, P), lambda i: (i, 0))
    y_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    rect = delta * dh
    return pl.pallas_call(
        functools.partial(_rinv_kernel, delta=delta),
        grid=(n // bt,),
        in_specs=[z_spec, z_spec, _mat_spec((delta, delta)),
                  _mat_spec((delta, delta)), _mat_spec((delta, dh)),
                  _mat_spec((delta, dh)), _mat_spec((1, rect)),
                  _mat_spec((1, rect))],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((n, delta, delta), dtype),
        interpret=interpret,
    )


def tile_irfft_epilogue_call(n: int, delta: int, P: int, dtype, *, bt: int,
                             activation: str = "none",
                             interpret: bool = False):
    """Compact-layout inverse tile DFT with the fused bias+activation tail:
    2x (n, P) planes + (n, 1) bias -> (n, delta, delta) real."""
    assert n % bt == 0
    if activation not in _TAIL_ACTIVATIONS:
        raise ValueError(f"unsupported kernel-tail activation "
                         f"{activation!r}: {tuple(_TAIL_ACTIVATIONS)}")
    dh = delta // 2 + 1
    z_spec = pl.BlockSpec((bt, P), lambda i: (i, 0))
    y_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    b_spec = pl.BlockSpec((bt, 1), lambda i: (i, 0))
    rect = delta * dh
    return pl.pallas_call(
        functools.partial(_rinv_epilogue_kernel, delta=delta,
                          activation=activation),
        grid=(n // bt,),
        in_specs=[z_spec, z_spec, _mat_spec((delta, delta)),
                  _mat_spec((delta, delta)), _mat_spec((delta, dh)),
                  _mat_spec((delta, dh)), _mat_spec((1, rect)),
                  _mat_spec((1, rect)), b_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((n, delta, delta), dtype),
        interpret=interpret,
    )


def tile_ifft_epilogue_call(n: int, delta: int, dtype, *, bt: int,
                            activation: str = "none",
                            interpret: bool = False):
    """Inverse tile DFT with a fused bias+activation tail.

    Inputs: 2x (n, delta, dh) complex planes + (n, 1) per-tile bias;
    output (n, delta, delta) real, already bias-shifted and activated.
    """
    assert n % bt == 0
    if activation not in _TAIL_ACTIVATIONS:
        raise ValueError(f"unsupported kernel-tail activation "
                         f"{activation!r}: {tuple(_TAIL_ACTIVATIONS)}")
    dh = delta // 2 + 1
    z_spec = pl.BlockSpec((bt, delta, dh), lambda i: (i, 0, 0))
    y_spec = pl.BlockSpec((bt, delta, delta), lambda i: (i, 0, 0))
    b_spec = pl.BlockSpec((bt, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_inv_epilogue_kernel, activation=activation),
        grid=(n // bt,),
        in_specs=[z_spec, z_spec, _mat_spec((delta, delta)),
                  _mat_spec((delta, delta)), _mat_spec((delta, dh)),
                  _mat_spec((delta, dh)), b_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((n, delta, delta), dtype),
        interpret=interpret,
    )
