"""Pure-jnp oracle for the tile DFT kernels."""
from repro.core.dft import rfft2_tiles, irfft2_tiles


def tile_fft_ref(x, delta):
    """(n, delta, delta) -> (Tr, Ti): (n, delta, delta//2+1)."""
    return rfft2_tiles(x, delta)


def tile_ifft_ref(Zr, Zi, delta):
    """(n, delta, delta//2+1) x2 -> (n, delta, delta)."""
    return irfft2_tiles(Zr, Zi, delta)
