"""Pure-jnp oracle for the tile DFT kernels."""
from repro.core.dft import (
    rfft2_tiles, irfft2_tiles, pack_half_spectrum, unpack_half_spectrum,
)


def tile_fft_ref(x, delta):
    """(n, delta, delta) -> (Tr, Ti): (n, delta, delta//2+1)."""
    return rfft2_tiles(x, delta)


def tile_ifft_ref(Zr, Zi, delta):
    """(n, delta, delta//2+1) x2 -> (n, delta, delta)."""
    return irfft2_tiles(Zr, Zi, delta)


def tile_rfft_ref(x, delta):
    """(n, delta, delta) -> compact planes (n, num_freq_real(delta)) x2."""
    Tr, Ti = rfft2_tiles(x, delta)
    return pack_half_spectrum(Tr, Ti, delta)


def tile_irfft_ref(Zr, Zi, delta):
    """Compact planes (n, P >= num_freq_real(delta)) x2 -> (n, delta, delta)."""
    Zr, Zi = unpack_half_spectrum(Zr, Zi, delta)
    return irfft2_tiles(Zr, Zi, delta)
