"""jit'd wrappers for the fused tile-DFT Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

import math

from repro.core.dft import dft_mats, compact_layout, num_freq_real
from repro.kernels.dft_tile.kernel import (
    tile_fft_call, tile_ifft_call, tile_ifft_epilogue_call,
    tile_rfft_call, tile_irfft_call, tile_irfft_epilogue_call,
)


DEFAULT_BT = 256                        # tile-batch block (grid rows/step)


def _pad_tiles(x, bt):
    n = x.shape[0]
    rem = (-n) % bt
    if rem:
        x = jnp.pad(x, ((0, rem),) + ((0, 0),) * (x.ndim - 1))
    return x


def resolve_bt(n: int, bt=None, slabs: int = 1) -> int:
    """Merge an explicit tile-batch block override over ``DEFAULT_BT``.

    ``None`` means "use the default"; explicit values must be positive
    ints and are honored verbatim (clamped to the tile count — padding a
    6-tile problem to a 256-wide block would be pure waste).  The default
    additionally *shrinks to fit*: it keeps the grid-step count the
    full-size default would need and balances the block across those
    steps, so padding is applied at most once for the whole batch instead
    of up to ``bt - 1`` ghost tiles per call (n=1000 gets bt=250, not a
    256-block padded to 1024).

    ``slabs > 1`` resolves for overlapped (sub-slab) execution: ``n`` is
    the un-slabbed tile count and the block is fitted to the *smallest*
    sub-slab, so one plan-time resolution covers every per-slab call
    without re-padding (mirrors ``cgemm.resolve_blocks(slabs=...)``).
    """
    if isinstance(slabs, bool) or not isinstance(slabs, int) or slabs < 1:
        raise ValueError(f"slabs must be a positive int, got {slabs!r}")
    n_fit = max(1, n // slabs)
    if bt is None:
        steps = max(1, math.ceil(n_fit / DEFAULT_BT))
        return max(1, math.ceil(n_fit / steps))
    if isinstance(bt, bool) or not isinstance(bt, int) or bt <= 0:
        raise ValueError(
            f"dft_tile block override bt must be a positive int or None, "
            f"got {bt!r}")
    return min(bt, max(n_fit, 1))


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_fft_pallas(x, *, delta: int = 16, bt: int | None = None,
                    interpret: bool | None = None):
    """Forward DFT of tiles: (n, delta, delta) -> 2x (n, delta, dh)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = x.shape[0]
    bt = resolve_bt(n, bt)
    xp = _pad_tiles(x, bt)
    Fr, Fi, Fhr, Fhi, *_ = dft_mats(delta)
    call = tile_fft_call(xp.shape[0], delta, x.dtype, bt=bt,
                         interpret=interpret)
    Tr, Ti = call(xp, Fr, Fi, Fhr, Fhi)
    return Tr[:n], Ti[:n]


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_ifft_pallas(Zr, Zi, *, delta: int = 16, bt: int | None = None,
                     interpret: bool | None = None):
    """Inverse DFT of tiles: 2x (n, delta, dh) -> (n, delta, delta)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = Zr.shape[0]
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    call = tile_ifft_call(Zrp.shape[0], delta, Zr.dtype, bt=bt,
                          interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi)[:n]


@functools.partial(jax.jit, static_argnames=("activation", "delta", "bt",
                                             "interpret"))
def tile_ifft_epilogue_pallas(Zr, Zi, bias, *, activation: str = "none",
                              delta: int = 16, bt: int | None = None,
                              interpret: bool | None = None):
    """Inverse DFT of tiles with the conv epilogue fused into the tail.

    ``bias`` is one scalar per tile — the bias of the output channel the
    tile belongs to — added (and the activation applied) while the block is
    still VMEM-resident: 2x (n, delta, dh) + (n,) -> (n, delta, delta).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = Zr.shape[0]
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    bp = _pad_tiles(bias.reshape(n, 1).astype(Zr.dtype), bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    call = tile_ifft_epilogue_call(Zrp.shape[0], delta, Zr.dtype, bt=bt,
                                   activation=activation,
                                   interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi, bp)[:n]


# --------------------------------------------------------------------------
# Compact-Hermitian (rfft) variants: flat (n, P) spectrum planes
# --------------------------------------------------------------------------

def _layout_operands(delta):
    """(store (1,P), src (1,rect), sgn (1,rect)) kernel operands."""
    store, src, sgn = compact_layout(delta)
    return store[None, :], src[None, :], sgn[None, :]


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_rfft_pallas(x, *, delta: int = 16, bt: int | None = None,
                     interpret: bool | None = None):
    """Forward DFT + compact-Hermitian pack: (n, delta, delta) -> 2x (n, P)
    with ``P = num_freq_real(delta)`` (~delta^2/2; see
    ``repro.core.dft.compact_layout``).  DC/Nyquist self-conjugate columns
    keep only their non-redundant rows, for even and odd delta alike."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = x.shape[0]
    bt = resolve_bt(n, bt)
    xp = _pad_tiles(x, bt)
    Fr, Fi, Fhr, Fhi, *_ = dft_mats(delta)
    store, _, _ = _layout_operands(delta)
    P = num_freq_real(delta)
    call = tile_rfft_call(xp.shape[0], delta, P, x.dtype, bt=bt,
                          interpret=interpret)
    Tr, Ti = call(xp, Fr, Fi, Fhr, Fhi, store)
    return Tr[:n], Ti[:n]


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_irfft_pallas(Zr, Zi, *, delta: int = 16, bt: int | None = None,
                      interpret: bool | None = None):
    """Compact-layout inverse DFT: 2x (n, P) -> (n, delta, delta) real.
    Accepts ``P >= num_freq_real(delta)`` (trailing padding is ignored)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, P = Zr.shape
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    _, src, sgn = _layout_operands(delta)
    call = tile_irfft_call(Zrp.shape[0], delta, P, Zr.dtype, bt=bt,
                           interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi, src, sgn)[:n]


@functools.partial(jax.jit, static_argnames=("activation", "delta", "bt",
                                             "interpret"))
def tile_irfft_epilogue_pallas(Zr, Zi, bias, *, activation: str = "none",
                               delta: int = 16, bt: int | None = None,
                               interpret: bool | None = None):
    """Compact-layout inverse DFT with the conv epilogue fused into the
    tail: 2x (n, P) + (n,) bias -> (n, delta, delta), bias-shifted and
    activated while the block is VMEM-resident."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, P = Zr.shape
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    bp = _pad_tiles(bias.reshape(n, 1).astype(Zr.dtype), bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    _, src, sgn = _layout_operands(delta)
    call = tile_irfft_epilogue_call(Zrp.shape[0], delta, P, Zr.dtype, bt=bt,
                                    activation=activation,
                                    interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi, src, sgn, bp)[:n]
