"""jit'd wrappers for the fused tile-DFT Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dft import dft_mats
from repro.kernels.dft_tile.kernel import (
    tile_fft_call, tile_ifft_call, tile_ifft_epilogue_call,
)


DEFAULT_BT = 256                        # tile-batch block (grid rows/step)


def _pad_tiles(x, bt):
    n = x.shape[0]
    rem = (-n) % bt
    if rem:
        x = jnp.pad(x, ((0, rem),) + ((0, 0),) * (x.ndim - 1))
    return x


def resolve_bt(n: int, bt=None) -> int:
    """Merge an explicit tile-batch block override over ``DEFAULT_BT``.

    ``None`` means "use the default"; explicit values must be positive
    ints.  Either way the block is clamped to the tile count (padding a
    6-tile problem to a 256-wide block would be pure waste).
    """
    if bt is None:
        bt = DEFAULT_BT
    if isinstance(bt, bool) or not isinstance(bt, int) or bt <= 0:
        raise ValueError(
            f"dft_tile block override bt must be a positive int or None, "
            f"got {bt!r}")
    return min(bt, max(n, 1))


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_fft_pallas(x, *, delta: int = 16, bt: int | None = None,
                    interpret: bool | None = None):
    """Forward DFT of tiles: (n, delta, delta) -> 2x (n, delta, dh)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = x.shape[0]
    bt = resolve_bt(n, bt)
    xp = _pad_tiles(x, bt)
    Fr, Fi, Fhr, Fhi, *_ = dft_mats(delta)
    call = tile_fft_call(xp.shape[0], delta, x.dtype, bt=bt,
                         interpret=interpret)
    Tr, Ti = call(xp, Fr, Fi, Fhr, Fhi)
    return Tr[:n], Ti[:n]


@functools.partial(jax.jit, static_argnames=("delta", "bt", "interpret"))
def tile_ifft_pallas(Zr, Zi, *, delta: int = 16, bt: int | None = None,
                     interpret: bool | None = None):
    """Inverse DFT of tiles: 2x (n, delta, dh) -> (n, delta, delta)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = Zr.shape[0]
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    call = tile_ifft_call(Zrp.shape[0], delta, Zr.dtype, bt=bt,
                          interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi)[:n]


@functools.partial(jax.jit, static_argnames=("activation", "delta", "bt",
                                             "interpret"))
def tile_ifft_epilogue_pallas(Zr, Zi, bias, *, activation: str = "none",
                              delta: int = 16, bt: int | None = None,
                              interpret: bool | None = None):
    """Inverse DFT of tiles with the conv epilogue fused into the tail.

    ``bias`` is one scalar per tile — the bias of the output channel the
    tile belongs to — added (and the activation applied) while the block is
    still VMEM-resident: 2x (n, delta, dh) + (n,) -> (n, delta, delta).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = Zr.shape[0]
    bt = resolve_bt(n, bt)
    Zrp, Zip = _pad_tiles(Zr, bt), _pad_tiles(Zi, bt)
    bp = _pad_tiles(bias.reshape(n, 1).astype(Zr.dtype), bt)
    *_, Fvr, Fvi, Wr, Wi = dft_mats(delta)
    call = tile_ifft_epilogue_call(Zrp.shape[0], delta, Zr.dtype, bt=bt,
                                   activation=activation,
                                   interpret=interpret)
    return call(Zrp, Zip, Fvr, Fvi, Wr, Wi, bp)[:n]
