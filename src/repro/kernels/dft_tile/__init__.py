from repro.kernels.dft_tile.ops import (
    tile_fft_pallas, tile_ifft_pallas, tile_ifft_epilogue_pallas,
    tile_rfft_pallas, tile_irfft_pallas, tile_irfft_epilogue_pallas,
    resolve_bt, DEFAULT_BT,
)
from repro.kernels.dft_tile.ref import (
    tile_fft_ref, tile_ifft_ref, tile_rfft_ref, tile_irfft_ref,
)

__all__ = ["tile_fft_pallas", "tile_ifft_pallas",
           "tile_ifft_epilogue_pallas", "tile_rfft_pallas",
           "tile_irfft_pallas", "tile_irfft_epilogue_pallas",
           "tile_fft_ref", "tile_ifft_ref", "tile_rfft_ref",
           "tile_irfft_ref", "resolve_bt", "DEFAULT_BT"]
