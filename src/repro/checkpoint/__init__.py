from repro.checkpoint.store import (save, save_async, wait_pending,
                                    latest_step, restore,
                                    save_plan_artifact, load_plan_artifact,
                                    has_plan_artifact, plan_artifact_path)

__all__ = ["save", "save_async", "wait_pending", "latest_step", "restore",
           "save_plan_artifact", "load_plan_artifact", "has_plan_artifact",
           "plan_artifact_path"]
