from repro.checkpoint.store import (save, save_async, wait_pending,
                                    latest_step, restore)

__all__ = ["save", "save_async", "wait_pending", "latest_step", "restore"]
