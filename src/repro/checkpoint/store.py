"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/  leaf files ``<flat.key.path>.npy`` + ``meta.json``.
Writes go to ``step_<N>.tmp`` then ``os.rename`` (atomic commit): a crash
mid-save never corrupts the latest checkpoint — restart picks the newest
*committed* step. ``save_async`` runs the serialisation on a worker thread so
the train loop keeps stepping (the arrays are fetched to host first, which is
the only synchronous part).

Elastic restore: leaves are loaded as host arrays and ``jax.device_put`` with
the *target* sharding, so a checkpoint taken on mesh A restores onto mesh B
(different data-axis size, different device count) without conversion steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None):
    """Synchronous atomic save of a pytree of (possibly sharded) arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    for k, v in host.items():
        np.save(os.path.join(tmp, k + ".npy"), v)
    meta = {"step": step, "keys": sorted(host), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree, *, extra: dict | None = None):
    """Fetch to host synchronously, serialise+commit on a worker thread."""
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    meta_extra = extra or {}

    def work():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        for k, v in host.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host),
                       "extra": meta_extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    os.makedirs(ckpt_dir, exist_ok=True)
    t = threading.Thread(target=work, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree``; device_put each leaf
    with the matching sharding from ``shardings`` (same structure) if given —
    this is the elastic-restore path (new mesh shape, new device count)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for k in flat_target:
        arr = np.load(os.path.join(d, k + ".npy"))
        if k in flat_shard and flat_shard[k] is not None:
            loaded[k] = jax.device_put(arr, flat_shard[k])
        else:
            loaded[k] = jax.numpy.asarray(arr)
    # unflatten via the target treedef
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef,
                                        [loaded[k] for k in keys]), meta
