"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<N>/  leaf files ``leaf_<i>.npy`` (named by
``meta.json``'s ``files`` map, keyed by ``jax.tree_util.keystr`` paths)
plus ``meta.json``.  Writes go to ``step_<N>.tmp`` then ``os.rename``
(atomic commit): a crash mid-save never corrupts the latest checkpoint —
restart picks the newest *committed* step. ``save_async`` runs the
serialisation on a worker thread so the train loop keeps stepping (the
arrays are fetched to host first, which is the only synchronous part).

Elastic restore: leaves are loaded as host arrays and ``jax.device_put``
with the *target* sharding, so a checkpoint taken on mesh A restores onto
mesh B (different data-axis size, different device count) without
conversion steps.  Checkpoints written by the pre-``keystr`` format (no
``files`` map in meta; keys joined from ``.key``/``.idx`` attributes) are
still restorable.

Exported plan artifacts (``repro.conv.export``) ride next to the
weights: ``save_plan_artifact`` attaches one ``plans.rpa`` per committed
step — one artifact per ``weights_version`` — and
``load_plan_artifact`` rehydrates it on a fresh worker.  A weight update
means a new step directory, i.e. a new artifact (the serve engine's
``update_weights`` likewise drops any loaded artifact and re-plans).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _legacy_key(path) -> str:
    """Pre-keystr key derivation.  BUG (kept only to restore old
    checkpoints): the ``str(p)`` fallback can collide distinct paths —
    e.g. a dict key ``"a.b"`` flattens identically to nested ``a -> b``,
    and path entry types that carry neither ``.key`` nor ``.idx`` all
    stringify the same way."""
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _flatten(tree, *, legacy: bool = False):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _legacy_key(path) if legacy else jax.tree_util.keystr(path)
        if key in out:
            raise ValueError(
                f"checkpoint: two leaves flatten to the same key {key!r}")
        out[key] = leaf
    return out


def _file_map(keys) -> dict:
    """Injective key -> filename map (index-based: keystr paths may hold
    arbitrary dict-key characters, so keys never become filenames)."""
    return {k: f"leaf_{i:05d}.npy" for i, k in enumerate(sorted(keys))}


def _write_step(tmp: str, host: dict, meta: dict) -> None:
    files = meta["files"]
    for k, v in host.items():
        np.save(os.path.join(tmp, files[k]), v)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)


def _make_meta(step: int, host: dict, extra, weights_version) -> dict:
    return {"step": step, "format": 2, "keys": sorted(host),
            "files": _file_map(host), "weights_version": weights_version,
            "extra": extra or {}}


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
         weights_version=None):
    """Synchronous atomic save of a pytree of (possibly sharded) arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    _write_step(tmp, host, _make_meta(step, host, extra, weights_version))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    return final


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree, *, extra: dict | None = None,
               weights_version=None):
    """Fetch to host synchronously, serialise+commit on a worker thread."""
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    meta = _make_meta(step, host, extra, weights_version)

    def work():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        _write_step(tmp, host, meta)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    os.makedirs(ckpt_dir, exist_ok=True)
    t = threading.Thread(target=work, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree``; device_put each leaf
    with the matching sharding from ``shardings`` (same structure) if given —
    this is the elastic-restore path (new mesh shape, new device count)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    legacy = "files" not in meta      # pre-keystr checkpoint layout
    files = meta.get("files", {})

    def fname(k):
        return files[k] if not legacy else k + ".npy"

    flat_target = _flatten(target_tree, legacy=legacy)
    flat_shard = _flatten(shardings, legacy=legacy) \
        if shardings is not None else {}
    loaded = {}
    for k in flat_target:
        arr = np.load(os.path.join(d, fname(k)))
        if k in flat_shard and flat_shard[k] is not None:
            loaded[k] = jax.device_put(arr, flat_shard[k])
        else:
            loaded[k] = jax.numpy.asarray(arr)
    # unflatten via the target treedef
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [_legacy_key(path) if legacy else jax.tree_util.keystr(path)
            for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef,
                                        [loaded[k] for k in keys]), meta


# --------------------------------------------------------------------------
# Exported plan artifacts next to weights (repro.conv.export)
# --------------------------------------------------------------------------

PLAN_ARTIFACT = "plans.rpa"


def plan_artifact_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}", PLAN_ARTIFACT)


def has_plan_artifact(ckpt_dir: str, step: int) -> bool:
    return os.path.exists(plan_artifact_path(ckpt_dir, step))


def save_plan_artifact(ckpt_dir: str, step: int, net, params, *,
                       weights_version=None) -> str:
    """Attach an AOT-exported plan artifact to a *committed* checkpoint
    step, so a fresh worker restoring these weights also skips the whole
    plan/prepare/compile sweep.  ``net`` is a ``NetworkPlan`` /
    ``BucketedNetworkPlan`` / label mapping; ``weights_version`` defaults
    to the step (one artifact per weights version — a new step is a new
    artifact)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(d):
        raise FileNotFoundError(
            f"no committed checkpoint step {step} under {ckpt_dir!r}; "
            "save the weights first")
    from repro.conv.export import export_network
    wv = step if weights_version is None else weights_version
    return export_network(net, plan_artifact_path(ckpt_dir, step),
                          params=params, weights_version=wv)


def load_plan_artifact(ckpt_dir: str, step: int, **load_kwargs):
    """Rehydrate the plan artifact attached to a checkpoint step
    (``repro.conv.export.load_network`` kwargs pass through)."""
    p = plan_artifact_path(ckpt_dir, step)
    if not os.path.exists(p):
        raise FileNotFoundError(
            f"checkpoint step {step} under {ckpt_dir!r} has no plan "
            f"artifact ({PLAN_ARTIFACT})")
    from repro.conv.export import load_network
    return load_network(p, **load_kwargs)
