"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--tuned] \
        [--json-out PATH]

Prints ``name,us_per_call,derived`` CSV rows (plus context columns) and
writes the same numbers as machine-readable JSON (``BENCH_conv.json``) so
the perf trajectory accumulates across runs.  Entries are either a bare
``us_per_call`` float or — for ``--tuned`` autotuner rows — a
``{"us_per_call": float, "config": {...}}`` dict recording the measured
winner alongside its timing (see ``benchmarks.bench_schema`` for the
tolerant schema every consumer shares).  The CI perf gate
(``benchmarks.compare_baseline``) diffs this file against the committed
``benchmarks/BENCH_baseline.json``.  Full-scale (arch x shape x mesh)
numbers come from the dry-run (`repro.launch.dryrun --all`) and are
summarised in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import io
import json
import sys


class _Tee(io.TextIOBase):
    """Pass stdout through while capturing it for CSV-row parsing."""

    def __init__(self, wrapped):
        self.wrapped = wrapped
        self.captured = io.StringIO()

    def write(self, s):
        self.captured.write(s)
        return self.wrapped.write(s)

    def flush(self):
        self.wrapped.flush()


def parse_csv_rows(text: str) -> dict:
    """``name,us_per_call[,...]`` rows -> {name: us_per_call} (header and
    ``#`` comment lines skipped; non-numeric second columns skipped)."""
    rows = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#") or line.startswith("name,"):
            continue
        parts = line.split(",")
        if len(parts) < 2:
            continue
        try:
            rows[parts[0]] = float(parts[1])
        except ValueError:
            continue
    return rows


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer layers / reps (CI-sized)")
    ap.add_argument("--tuned", action="store_true",
                    help="add measured-autotuner rows (winner config "
                         "recorded alongside the timing)")
    ap.add_argument("--json-out", default="BENCH_conv.json",
                    help="machine-readable name->us_per_call output "
                         "('' disables)")
    ap.add_argument("--analyze-out", default="",
                    help="also write the plan-lint profile sweep "
                         "(repro.conv.analyze) as a JSON artifact riding "
                         "the benchmark run ('' disables)")
    args = ap.parse_args(argv)

    tee = _Tee(sys.stdout)
    sys.stdout = tee
    try:
        from benchmarks import table1_layers, fig56_speedup, fig78_memrate
        print("name,us_per_call,derived")
        table1_layers.main(["--batch", "1", "--reps", "2"] if args.quick
                           else ["--batch", "2", "--reps", "3"])
        sys.stdout.flush()
        fig56_speedup.main(["--quick", "--reps", "3"] if args.quick
                           else ["--reps", "5"])
        sys.stdout.flush()
        fig78_memrate.main()
        sys.stdout.flush()
        _spectrum_rows(quick=args.quick)
        sys.stdout.flush()
        _conv_roofline_rows()
        sys.stdout.flush()
    finally:
        sys.stdout = tee.wrapped

    rows = parse_csv_rows(tee.captured.getvalue())
    rows.update(_overlap_rows(quick=args.quick))
    rows.update(_serve_rows(quick=args.quick))
    rows.update(_coldstart_rows(quick=args.quick))
    if args.tuned:
        rows.update(_tuned_rows(quick=args.quick))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(rows, fh, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} entries to {args.json_out}")
    if args.analyze_out:
        _analyze_artifact(args.analyze_out, quick=args.quick)
    return rows


def _analyze_artifact(path: str, quick: bool = False) -> None:
    """Plan-lint profile artifact riding the benchmark run: every
    registered backend x schedule swept over the paper geometries, so the
    perf numbers ship with the structural facts (collective counts, dtype
    flow, peak live bytes) that make them interpretable.  Violations are
    fatal — a timing for a plan that breaks its invariants is
    meaningless."""
    from repro.conv.analyze import sweep
    profiles, violations = sweep(batch=2, limit=3 if quick else None,
                                 progress=lambda s: print(f"# {s}"))
    payload = {k: p.to_dict() for k, p in profiles.items()}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print(f"# wrote {len(payload)} plan-lint profiles to {path}")
    if violations:
        raise SystemExit(
            f"plan-lint: {len(violations)} violation(s) during the "
            f"benchmark analyze sweep")


def _tuned_rows(quick: bool = True) -> dict:
    """Measured-autotuner entries: the winner's timing plus the chosen
    (backend, schedule, block) config, in the dict entry form."""
    from repro.conv import autotune

    shapes = [("autotune/c8o16s32", (1, 8, 32, 32), (16, 8, 3, 3), 1)]
    if not quick:
        shapes.append(
            ("autotune/c16o32s64", (1, 16, 64, 64), (32, 16, 3, 3), 1))
    out = {}
    for name, x_shape, k_shape, padding in shapes:
        w = autotune.tune(x_shape, k_shape, padding=padding)
        us = w.us_per_call
        config = {"backend": w.backend, "schedule": w.schedule,
                  "bm": w.bm, "bn": w.bn, "bk": w.bk, "dft_bt": w.dft_bt,
                  "spectrum": w.spectrum, "source": w.source}
        if us is None:
            # cost-model fallback (measurement disabled): time the pick so
            # the row still carries a number
            import jax.numpy as jnp
            import numpy as np
            from repro.conv import plan_conv
            plan = plan_conv(x_shape, k_shape, padding=padding,
                             backend=w.backend, schedule=w.schedule)
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
            k = jnp.asarray(rng.standard_normal(k_shape), jnp.float32)
            us = autotune.measure_us(plan, x, k)
        print(f"{name},{us:.1f},{config['backend']}/{config['schedule']}")
        out[name] = {"us_per_call": float(us), "config": config}
    return out


def _spectrum_rows(quick: bool = True):
    """Real (compact Hermitian) vs complex (full-spectrum twin) frequency
    layout on bandwidth-bound Table-I geometries, same backend/schedule —
    isolating what the rfft fast path buys."""
    import jax.numpy as jnp
    import numpy as np
    from repro.conv import autotune, plan_conv

    layers = [("vgg-conv3.2", (1, 256, 56, 56), (256, 256, 3, 3), 1)]
    if not quick:
        layers.append(("vgg-conv4.2", (1, 512, 28, 28), (512, 512, 3, 3), 1))
    print("# spectrum: compact-Hermitian (real) vs full-spectrum (complex), "
          "fft-xla/local — name,us_per_call,spectrum")
    rng = np.random.default_rng(0)
    for name, x_shape, k_shape, padding in layers:
        x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
        k = jnp.asarray(rng.standard_normal(k_shape), jnp.float32)
        for spectrum in ("real", "complex"):
            plan = plan_conv(x_shape, k_shape, padding=padding,
                             backend="fft-xla", spectrum=spectrum)
            us = autotune.measure_us(plan, x, k, reps=2 if quick else 3)
            print(f"spectrum/{name}/{spectrum},{us:.1f},{spectrum}")


_OVERLAP_WORKER = r"""
import sys, json, time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.conv import plan_conv
spec = json.loads(sys.argv[1])
assert jax.device_count() == spec["ndev"], jax.device_count()
mesh = make_mesh((spec["ndev"], 1), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal(
    (spec["B"], spec["C"], spec["H"], spec["W"])), jnp.float32)
k = jnp.asarray(rng.standard_normal(
    (spec["Co"], spec["C"], spec["kh"], spec["kh"])), jnp.float32)
out = {}
for ov in spec["overlaps"]:
    plan = plan_conv(x.shape, k.shape, padding=spec["pad"],
                     schedule="nfft", mesh=mesh, overlap=ov)
    f = jax.jit(plan)
    jax.block_until_ready(f(x, k))
    ts = []
    for _ in range(spec["reps"]):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x, k))
        ts.append(time.perf_counter() - t0)
    out[ov] = float(np.median(ts)) * 1e6
print("RESULT" + json.dumps(out))
"""


def _overlap_rows(quick: bool = True) -> dict:
    """Comm/compute-overlapped nfft vs the synchronous baseline on a
    4-device emulated NUMA mesh (device-count forcing + latency-hiding
    scheduler flags from ``repro.launch.env``; subprocess so the parent
    keeps its real device).  Dict entries record the slab count next to
    the timing."""
    import os
    import subprocess

    from repro.configs.paper_convs import TABLE1
    from repro.launch.env import xla_flags

    ndev, batch = 4, 16                 # b_loc=4: slab:4 doesn't clamp
    # Rconv2.2 is the comm-heavy geometry (Cout=64: a2a bytes per cgemm
    # flop is Table I's highest) where overlap wins on an otherwise-idle
    # host; the compute-heavy layers in the full sweep are the honest
    # neutral cases (auto picks off there — trust the measurement).
    names = ["Rconv2.2"] if quick else ["Rconv2.2", "Rconv4.2", "Vconv5"]
    overlaps = ["off", "slab:2", "slab:4"]
    byname = {l.name: l for l in TABLE1}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = xla_flags(ndev)
    print(f"# overlap: nfft sub-slab pipelines on a {ndev}-device emulated "
          "mesh — name,us_per_call,overlap")
    out = {}
    for name in names:
        lay = byname[name]
        spec = dict(B=batch, C=lay.C, Co=lay.Cout, H=lay.H, W=lay.W,
                    kh=lay.kh, pad=lay.pad, ndev=ndev, overlaps=overlaps,
                    reps=9 if name == "Rconv2.2" else 5)
        r = subprocess.run(
            [sys.executable, "-c", _OVERLAP_WORKER, json.dumps(spec)],
            env=env, capture_output=True, text=True, timeout=1200)
        if r.returncode != 0:
            print(f"# overlap/{name}: worker failed: {r.stderr[-500:]}")
            continue
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT")][0]
        for ov, us in json.loads(line[len("RESULT"):]).items():
            tag = ov.replace("slab:", "slab")   # off | slab2 | slab4
            print(f"overlap/{name}/{tag},{us:.1f},{ov}")
            out[f"overlap/{name}/{tag}"] = {
                "us_per_call": float(us),
                "config": {"schedule": "nfft", "overlap": ov,
                           "num_slabs": 1 if ov == "off"
                           else int(ov.split(":")[1]),
                           "ndev": ndev, "batch": batch}}
    return out


_COLDSTART_WORKER = r"""
import sys, json
import jax.numpy as jnp, numpy as np
from repro.conv import Epilogue, NetworkConv
from repro.launch.batcher import BucketPolicy, ServeEngine

spec = json.loads(sys.argv[1])
ep = Epilogue(bias=True, activation="relu")

def make_layers(b):
    return (
        NetworkConv("s1", (b, 16, 32, 32), (32, 16, 3, 3),
                    padding=1, epilogue=ep),
        NetworkConv("s2", (b, 32, 32, 32), (32, 32, 3, 3),
                    padding=1, epilogue=ep),
    )

rng = np.random.default_rng(0)
def init(shape, s=0.05):
    return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)
kernels = {l.name: init(l.k_shape) for l in make_layers(1)}
biases = {l.name: init((l.k_shape[0],)) for l in make_layers(1)}

def forward(prepared, x):
    for name in prepared:
        x = prepared[name](x, bias=biases[name])
    return x

engine = ServeEngine(
    make_layers, kernels, policy=BucketPolicy(max_batch=spec["max_batch"]),
    forward=forward, timing="per-batch", collect_results=False,
    backend="fft-xla",
    load_plans=spec["artifact"] if spec["mode"] == "aot" else None)
assert engine.plan_source == spec["mode"], engine.plan_source
if spec["mode"] == "live":
    engine.export_plans(spec["artifact"])
print("RESULT" + json.dumps({"startup_s": engine.startup_s}))
"""


def _coldstart_rows(quick: bool = True) -> dict:
    """Fleet cold-start: ServeEngine startup wall-time (plan + prepare +
    compile + warm, measured inside the constructor) in a FRESH process,
    live-planned vs rehydrated from the AOT plan artifact the live
    worker exported (``repro.conv.export``).  Two subprocesses so both
    sides pay real process cold-start — no warm jax caches leak in from
    the parent."""
    import os
    import subprocess
    import tempfile

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    max_batch = 4 if quick else 8
    out = {}
    print("# coldstart: ServeEngine startup in a fresh process, live "
          "plan+prepare+compile vs AOT plan-artifact rehydration — "
          "name,us_per_call,source")
    with tempfile.TemporaryDirectory() as td:
        artifact = os.path.join(td, "plans.rpa")
        for mode in ("live", "aot"):
            spec = {"mode": mode, "artifact": artifact,
                    "max_batch": max_batch}
            r = subprocess.run(
                [sys.executable, "-c", _COLDSTART_WORKER,
                 json.dumps(spec)],
                env=env, capture_output=True, text=True, timeout=1200)
            if r.returncode != 0:
                print(f"# coldstart/{mode}: worker failed: "
                      f"{r.stderr[-500:]}")
                return out
            line = [ln for ln in r.stdout.splitlines()
                    if ln.startswith("RESULT")][0]
            s = json.loads(line[len("RESULT"):])["startup_s"]
            print(f"coldstart/{mode},{s * 1e6:.1f},{mode}")
            out[f"coldstart/{mode}"] = {
                "us_per_call": float(s) * 1e6,
                "config": {"source": mode, "max_batch": max_batch,
                           "n_layers": 2, "artifact": "plans.rpa"}}
    live = out.get("coldstart/live", {}).get("us_per_call")
    aot = out.get("coldstart/aot", {}).get("us_per_call")
    if live is not None and aot is not None and not aot < live:
        raise SystemExit(
            f"coldstart: AOT rehydration ({aot / 1e6:.2f}s) not faster "
            f"than live planning ({live / 1e6:.2f}s)")
    return out


def _serve_rows(quick: bool = True) -> dict:
    """Serving-SLO rows: the continuous-batching engine
    (``repro.launch.batcher``) on a reproducible ragged burst trace,
    emitting ``serve/<bucket>/{p50,p99,occupancy}`` in the dict entry
    form (percentiles riding the tolerated ``percentiles`` field) so
    the baseline gate holds serving latency, not just kernel time."""
    import jax.numpy as jnp
    import numpy as np
    from repro.conv import Epilogue, NetworkConv
    from repro.launch.batcher import (
        BucketPolicy, ServeEngine, run_trace, synthetic_trace)

    max_batch = 4 if quick else 8
    n_requests = 16 if quick else 32
    ep = Epilogue(bias=True, activation="relu")

    def make_layers(b):
        return (
            NetworkConv("s1", (b, 16, 32, 32), (32, 16, 3, 3),
                        padding=1, epilogue=ep),
            NetworkConv("s2", (b, 32, 32, 32), (32, 32, 3, 3),
                        padding=1, epilogue=ep),
        )

    rng = np.random.default_rng(0)

    def init(shape, s=0.05):
        return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)

    kernels = {l.name: init(l.k_shape) for l in make_layers(1)}
    biases = {l.name: init((l.k_shape[0],)) for l in make_layers(1)}

    def forward(prepared, x):
        for name in prepared:
            x = prepared[name](x, bias=biases[name])
        return x

    engine = ServeEngine(make_layers, kernels,
                         policy=BucketPolicy(max_batch=max_batch),
                         forward=forward, timing="per-batch",
                         collect_results=False, backend="fft-xla")
    trace = synthetic_trace(n_requests=n_requests, max_batch=max_batch,
                            rate_rps=1.0, seed=0)
    inputs = {}

    def make_input(b, image):
        if b not in inputs:
            inputs[b] = init((b, 16, 32, 32), 1.0)
        return inputs[b]

    rep = run_trace(engine, trace, make_input=make_input,
                    realtime=False)        # deterministic burst replay
    assert rep["plan_cache_misses_after_warmup"] == 0, \
        "serve bench planned on the hot path"
    rows = engine.bench_rows(prefix="serve")
    print("# serve: continuous-batching engine, ragged burst trace "
          f"(n={n_requests}, max_batch={max_batch}) — "
          "name,us_per_call,metric")
    for name in sorted(rows):
        metric = name.rsplit("/", 1)[1]
        print(f"{name},{rows[name]['us_per_call']:.1f},{metric}")
    return rows


def _conv_roofline_rows():
    """§Perf conv hillclimb rows (from the saved production-mesh analysis;
    regenerate with `python -m benchmarks.conv_roofline`)."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "conv_roofline_vconv42.json")
    if not os.path.exists(path):
        print("# conv_roofline: no cached analysis; run "
              "`python -m benchmarks.conv_roofline`")
        return
    print("# conv_roofline Vconv4.2 (cached 16x16-mesh analysis; wall on "
          "8-dev host) — name,us_per_call,derived(coll bytes/dev)")
    with open(path) as fh:
        res = json.load(fh)
    for v, r in res.items():
        wall = r.get("wall", {}).get("wall_s", 0.0)
        print(f"conv_roofline/Vconv4.2/{v},{wall*1e6:.0f},"
              f"{r['analysis']['coll_bytes_dev']:.3e}")


if __name__ == "__main__":
    main()
