"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows (plus context columns).
Full-scale (arch x shape x mesh) numbers come from the dry-run
(`repro.launch.dryrun --all`) and are summarised in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer layers / reps (CI-sized)")
    args = ap.parse_args()

    from benchmarks import table1_layers, fig56_speedup, fig78_memrate
    print("name,us_per_call,derived")
    table1_layers.main(["--batch", "1", "--reps", "2"] if args.quick
                       else ["--batch", "2", "--reps", "3"])
    sys.stdout.flush()
    fig56_speedup.main(["--quick", "--reps", "3"] if args.quick
                       else ["--reps", "5"])
    sys.stdout.flush()
    fig78_memrate.main()
    sys.stdout.flush()
    _conv_roofline_rows()


def _conv_roofline_rows():
    """§Perf conv hillclimb rows (from the saved production-mesh analysis;
    regenerate with `python -m benchmarks.conv_roofline`)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "conv_roofline_vconv42.json")
    if not os.path.exists(path):
        print("# conv_roofline: no cached analysis; run "
              "`python -m benchmarks.conv_roofline`")
        return
    print("# conv_roofline Vconv4.2 (cached 16x16-mesh analysis; wall on "
          "8-dev host) — name,us_per_call,derived(coll bytes/dev)")
    with open(path) as fh:
        res = json.load(fh)
    for v, r in res.items():
        wall = r.get("wall", {}).get("wall_s", 0.0)
        print(f"conv_roofline/Vconv4.2/{v},{wall*1e6:.0f},"
              f"{r['analysis']['coll_bytes_dev']:.3e}")


if __name__ == "__main__":
    main()
