"""Refresh the committed perf baseline (``benchmarks/BENCH_baseline.json``).

    # re-measure on this machine (the CI-sized quick run) and write:
    python -m benchmarks.update_baseline

    # or adopt an existing BENCH_conv.json (e.g. downloaded from a CI run
    # on the runner hardware the gate compares against):
    python -m benchmarks.update_baseline --from BENCH_conv.json

The output is normalized to the ``{name: {"us_per_call": float,
"config": {...}}}`` schema (see ``benchmarks.bench_schema``) so the gate
never has to guess entry shapes.  Commit the result; the CI perf gate
(``benchmarks.compare_baseline``) compares every smoke run against it.
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.bench_schema import normalize

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                            "BENCH_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--from", dest="src", default=None,
                    help="adopt an existing bench JSON instead of "
                         "re-measuring")
    ap.add_argument("--out", default=_DEFAULT_OUT)
    ap.add_argument("--full", action="store_true",
                    help="measure with the full (non --quick) bench run")
    args = ap.parse_args(argv)

    if args.src:
        with open(args.src) as fh:
            data = normalize(json.load(fh))
    else:
        from benchmarks import run as bench_run
        rows = bench_run.main(([] if args.full else ["--quick"])
                              + ["--json-out", ""])
        data = normalize(rows)

    if not data:
        raise SystemExit("refusing to write an empty baseline")
    with open(args.out, "w") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(data)} baseline entries to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
