"""Paper Figs. 5-6: nFFT vs wFFT speedup.

The paper measures wall time on 8 NUMA nodes of an FT-2000plus. Here the 8
"NUMA nodes" are 8 forced host devices on a (2 data x 4 model) mesh — a real
multi-device execution of both schedules (spawned in a subprocess so the
parent keeps one device). Two measurements per layer:

  * wall-time speedup nFFT/wFFT on the 8-way host mesh (the paper's Fig 5-6
    quantity, hardware-adapted),
  * hot-stage collective bytes per strategy from the compiled HLO (the
    TPU-relevant proxy for the paper's remote-memory-access reduction).

CSV: name,us_per_call,derived   (derived = speedup nFFT over wFFT)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time
import jax, jax.numpy as jnp, numpy as np
from repro.conv import plan_conv
from repro.compat import make_mesh
from repro.launch.roofline import parse_collectives
mesh = make_mesh((2, 4), ("data", "model"))
spec = json.loads(sys.argv[1])
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal(
    (spec["B"], spec["C"], spec["H"], spec["W"])), jnp.float32)
k = jnp.asarray(rng.standard_normal(
    (spec["Co"], spec["C"], spec["kh"], spec["kh"])), jnp.float32)
out = {}
for strat in ("nfft", "wfft"):
    f = jax.jit(plan_conv(x.shape, k.shape, schedule=strat, mesh=mesh,
                          padding=spec["pad"]))
    y = f(x, k)
    jax.block_until_ready(y)
    ts = []
    for _ in range(spec["reps"]):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x, k))
        ts.append(time.perf_counter() - t0)
    coll = parse_collectives(f.lower(x, k).compile().as_text())
    out[strat] = {"t": float(np.median(ts)),
                  "coll_bytes": coll["total_bytes"],
                  "coll_counts": coll["counts"]}
print("RESULT" + json.dumps(out))
"""


def run_layer(name, B, C, Co, H, W, kh, pad, reps=5):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    spec = dict(B=B, C=C, Co=Co, H=H, W=W, kh=kh, pad=pad, reps=reps)
    r = subprocess.run([sys.executable, "-c", _WORKER, json.dumps(spec)],
                       env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"{name}: {r.stderr[-2000:]}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


# reduced-batch versions of representative Table-I layers (CPU-tractable)
LAYERS = [
    ("Vconv3.1", 4, 128, 256, 56, 56, 3, 1),
    ("Vconv4.2", 4, 512, 512, 28, 28, 3, 1),
    ("Vconv5", 8, 512, 512, 14, 14, 3, 1),
    ("Aconv3", 8, 256, 384, 13, 13, 3, 1),
    ("Rconv4.2", 8, 256, 256, 14, 14, 3, 1),
    ("Rconv5.2", 8, 512, 512, 7, 7, 3, 1),
]


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    layers = LAYERS[:3] if args.quick else LAYERS
    print("# Fig 5-6 — name,us_per_call(nFFT),derived(speedup nFFT/wFFT)"
          ",wfft_us,coll_bytes_nfft,coll_bytes_wfft")
    for (name, B, C, Co, H, W, kh, pad) in layers:
        res = run_layer(name, B, C, Co, H, W, kh, pad, reps=args.reps)
        sp = res["wfft"]["t"] / res["nfft"]["t"]
        print(f"fig56/{name},{res['nfft']['t']*1e6:.0f},{sp:.2f},"
              f"{res['wfft']['t']*1e6:.0f},"
              f"{res['nfft']['coll_bytes']},{res['wfft']['coll_bytes']}")


if __name__ == "__main__":
    main()
