"""Schema helpers for ``BENCH_conv.json`` / ``BENCH_baseline.json``.

Two entry forms are accepted, so tuned runs can record the chosen config
alongside the timing without breaking plain-float consumers:

    {"table1/Vconv1.2": 123.4,                          # legacy: bare float
     "autotune/conv3": {"us_per_call": 88.1,            # rich: dict
                        "config": {"backend": "fft-xla", ...}}}

``normalize`` maps both onto ``{name: {"us_per_call": float,
"config": dict}}``; every consumer (CI smoke assertion, the perf-regression
gate, ``update_baseline``) goes through it.
"""
from __future__ import annotations

import json


def normalize_entry(name: str, value):
    """One entry -> ``{"us_per_call": float, "config": dict}`` (raises
    ``ValueError`` on anything else)."""
    if isinstance(value, bool):
        raise ValueError(f"bench entry {name!r}: bool is not a timing")
    if isinstance(value, (int, float)):
        return {"us_per_call": float(value), "config": {}}
    if isinstance(value, dict):
        if "us_per_call" not in value:
            raise ValueError(
                f"bench entry {name!r}: dict form requires 'us_per_call', "
                f"got keys {sorted(value)}")
        us = value["us_per_call"]
        if isinstance(us, bool) or not isinstance(us, (int, float)):
            raise ValueError(
                f"bench entry {name!r}: us_per_call must be a number, "
                f"got {us!r}")
        config = value.get("config", {})
        if not isinstance(config, dict):
            raise ValueError(
                f"bench entry {name!r}: config must be a dict, "
                f"got {type(config).__name__}")
        return {"us_per_call": float(us), "config": config}
    raise ValueError(
        f"bench entry {name!r}: expected float or "
        f"{{'us_per_call': float, 'config': {{...}}}}, "
        f"got {type(value).__name__}")


def normalize(data: dict) -> dict:
    """Whole-file normalization; raises ``ValueError`` on malformed input."""
    if not isinstance(data, dict):
        raise ValueError(f"bench JSON must be an object, "
                         f"got {type(data).__name__}")
    return {str(name): normalize_entry(name, value)
            for name, value in data.items()}


def load_normalized(path: str) -> dict:
    with open(path) as fh:
        return normalize(json.load(fh))
