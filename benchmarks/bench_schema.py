"""Schema helpers for ``BENCH_conv.json`` / ``BENCH_baseline.json``.

Two entry forms are accepted, so tuned runs can record the chosen config
alongside the timing without breaking plain-float consumers:

    {"table1/Vconv1.2": 123.4,                          # legacy: bare float
     "autotune/conv3": {"us_per_call": 88.1,            # rich: dict
                        "config": {"backend": "fft-xla", ...}},
     "serve/b4/p99": {"us_per_call": 910.0,             # serving SLO row
                      "percentiles": {"p50": 618.0, "p99": 910.0},
                      "config": {"mode": "bucketed", ...}}}

``normalize`` maps all of them onto ``{name: {"us_per_call": float,
"config": dict}}`` — plus an optional ``percentiles`` key (str -> float)
preserved verbatim when present, so serving-latency rows round-trip
through ``compare_baseline`` / ``update_baseline`` while plain-float
consumers keep reading ``us_per_call`` alone.  Every consumer (CI smoke
assertion, the perf-regression gate, ``update_baseline``) goes through
it.
"""
from __future__ import annotations

import json


def normalize_entry(name: str, value):
    """One entry -> ``{"us_per_call": float, "config": dict}`` plus an
    optional tolerated ``percentiles`` dict (raises ``ValueError`` on
    anything else)."""
    if isinstance(value, bool):
        raise ValueError(f"bench entry {name!r}: bool is not a timing")
    if isinstance(value, (int, float)):
        return {"us_per_call": float(value), "config": {}}
    if isinstance(value, dict):
        if "us_per_call" not in value:
            raise ValueError(
                f"bench entry {name!r}: dict form requires 'us_per_call', "
                f"got keys {sorted(value)}")
        us = value["us_per_call"]
        if isinstance(us, bool) or not isinstance(us, (int, float)):
            raise ValueError(
                f"bench entry {name!r}: us_per_call must be a number, "
                f"got {us!r}")
        config = value.get("config", {})
        if not isinstance(config, dict):
            raise ValueError(
                f"bench entry {name!r}: config must be a dict, "
                f"got {type(config).__name__}")
        out = {"us_per_call": float(us), "config": config}
        pcts = value.get("percentiles")
        if pcts is not None:
            if not isinstance(pcts, dict) or not all(
                    isinstance(v, (int, float))
                    and not isinstance(v, bool)
                    for v in pcts.values()):
                raise ValueError(
                    f"bench entry {name!r}: percentiles must map names "
                    f"to numbers, got {pcts!r}")
            out["percentiles"] = {str(k): float(v)
                                  for k, v in pcts.items()}
        return out
    raise ValueError(
        f"bench entry {name!r}: expected float or "
        f"{{'us_per_call': float, 'percentiles'?: {{...}}, "
        f"'config': {{...}}}}, got {type(value).__name__}")


def normalize(data: dict) -> dict:
    """Whole-file normalization; raises ``ValueError`` on malformed input."""
    if not isinstance(data, dict):
        raise ValueError(f"bench JSON must be an object, "
                         f"got {type(data).__name__}")
    return {str(name): normalize_entry(name, value)
            for name, value in data.items()}


def load_normalized(path: str) -> dict:
    with open(path) as fh:
        return normalize(json.load(fh))
