"""§Perf hillclimb #3: the paper's own workload (FFT conv) on the
production 16x16 mesh — baseline wFFT, paper-faithful nFFT, then
beyond-paper variants:

  repG      : replicate the (cheap) kernel transform instead of a2a-ing G
  bf16      : bf16 CGEMM operands with f32 accumulation (halves hot bytes,
              doubles MXU rate)
  4m        : 4-matmul complex product (vs default 3M) for comparison
  ep_fused  : bias+relu epilogue FUSED into stage 4 inside shard_map (the
              elementwise tail runs on each rank's 1/N output slab)
  ep_unfused: the same bias+relu as separate XLA ops on the gathered
              output (what per-layer model code used to do) — the
              fused-vs-unfused delta is the epilogue-fusion win

Per variant: per-device collective bytes (compiled HLO, loop-trip aware),
analytic CGEMM/transform FLOPs from ConvSpec, roofline terms, plus measured
wall time on an 8-device host mesh (2x4) — one-shot ``plan(x, k)`` AND the
prepared ``plan.prepare(k)`` path, so the stage-2 amortization is a
measured column, not an assertion.

CSV: name,us_per_call(8dev wall),us_per_call_prepared,derived(collective
bytes/dev @pod256)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
from repro.launch import env as _env
_env.apply(%(ndev)d)   # device-count forcing + latency-hiding scheduler
import sys, json, time
import jax, jax.numpy as jnp, numpy as np
from repro.conv import plan_conv
from repro.compat import make_mesh
from repro.launch.roofline import parse_collectives, roofline_terms, \
    PEAK_FLOPS, HBM_BW
mesh = make_mesh((%(nd)d, %(nm)d), ("data", "model"))
spec = json.loads(sys.argv[1])
variant = spec["variant"]
kw = dict(padding=spec["pad"], schedule="nfft", mesh=mesh)
if variant == "wfft":
    kw["schedule"] = "wfft"
elif variant in ("nfft", "nfft_ep_unfused"):
    pass
elif variant == "nfft_ep_fused":
    from repro.conv import Epilogue
    kw["epilogue"] = Epilogue(bias=True, activation="relu")
elif variant == "nfft_repG":
    kw["replicate_kernel_transform"] = True
elif variant == "nfft_repG_bf16":
    kw["replicate_kernel_transform"] = True
    kw["compute_dtype"] = jnp.bfloat16
elif variant == "nfft_4m":
    kw["three_m"] = False
elif variant == "nfft_overlap2":
    kw["overlap"] = "slab:2"
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal(
    (spec["B"], spec["C"], spec["H"], spec["W"])), jnp.float32)
k = jnp.asarray(rng.standard_normal(
    (spec["Co"], spec["C"], spec["kh"], spec["kh"])), jnp.float32)
b = jnp.asarray(rng.standard_normal((spec["Co"],)), jnp.float32)
plan = plan_conv(x.shape, k.shape, **kw)
if variant == "nfft_ep_fused":
    f = jax.jit(lambda x, k, b: plan(x, k, bias=b))
    f_args = (x, k, b)
elif variant == "nfft_ep_unfused":
    # the pre-fusion model-layer pattern: separate bias+relu ops on the
    # already-gathered output, outside shard_map
    f = jax.jit(lambda x, k, b: jax.nn.relu(
        plan(x, k) + b[None, :, None, None]))
    f_args = (x, k, b)
else:
    f = jax.jit(plan)
    f_args = (x, k)
lowered = f.lower(*f_args)
comp = lowered.compile()
coll = parse_collectives(comp.as_text())
out = {"coll_bytes_dev": coll["total_bytes"], "counts": coll["counts"]}
# prepared plan: stage 2 + (nfft) boundary a2a #2 amortized away — measure
# the saving instead of asserting it.
prepared = plan.prepare(k, weights_version=0)
if variant == "nfft_ep_fused":
    fp = jax.jit(lambda x, b: prepared(x, bias=b))
    fp_args = (x, b)
elif variant == "nfft_ep_unfused":
    fp = jax.jit(lambda x, b: jax.nn.relu(
        prepared(x) + b[None, :, None, None]))
    fp_args = (x, b)
else:
    fp = jax.jit(prepared)
    fp_args = (x,)
coll_p = parse_collectives(fp.lower(*fp_args).compile().as_text())
out["coll_bytes_dev_prepared"] = coll_p["total_bytes"]
out["counts_prepared"] = coll_p["counts"]
def _median_wall(fn, *args):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(spec["reps"]):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
if spec["measure"]:
    out["wall_s"] = _median_wall(f, *f_args)
    out["wall_prepared_s"] = _median_wall(fp, *fp_args)
print("RESULT" + json.dumps(out))
"""

VARIANTS = ("wfft", "nfft", "nfft_ep_fused", "nfft_ep_unfused",
            "nfft_repG", "nfft_repG_bf16", "nfft_4m", "nfft_overlap2")


def run(layer, variant, *, ndev, nd, nm, measure, reps=3):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    spec = dict(layer, variant=variant, measure=measure, reps=reps)
    worker = _WORKER % dict(ndev=ndev, nd=nd, nm=nm)
    r = subprocess.run([sys.executable, "-c", worker, json.dumps(spec)],
                       env=env, capture_output=True, text=True,
                       timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"{variant}: {r.stderr[-3000:]}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--layer", default="Vconv4.2")
    ap.add_argument("--batch", type=int, default=128,
                    help="analysis batch (production scale)")
    ap.add_argument("--measure-batch", type=int, default=8)
    ap.add_argument("--json-out", default="")
    ap.add_argument("--variants", default="",
                    help="comma list to (re)generate a subset; with "
                         "--json-out, new results merge into the existing "
                         "file instead of replacing it")
    args = ap.parse_args(argv)

    from repro.configs.paper_convs import TABLE1
    lay = {l.name: l for l in TABLE1}[args.layer]
    base = dict(C=lay.C, Co=lay.Cout, H=lay.H, W=lay.W, kh=lay.kh,
                pad=lay.pad)

    chosen = VARIANTS
    if args.variants:
        chosen = tuple(v.strip() for v in args.variants.split(",")
                       if v.strip())
        unknown = [v for v in chosen if v not in VARIANTS]
        if unknown:
            raise SystemExit(f"unknown variants {unknown} "
                             f"(choose from {VARIANTS})")

    print(f"# conv_roofline {args.layer}: analysis B={args.batch} on 16x16 "
          f"(256 chips); wall time B={args.measure_batch} on 2x4 host mesh")
    print("name,us_per_call,us_per_call_prepared,derived")
    results = {}
    if args.variants and args.json_out and os.path.exists(args.json_out):
        with open(args.json_out) as fh:
            results.update(json.load(fh))   # subset runs merge, not replace
    for v in chosen:
        ana = run(dict(base, B=args.batch), v, ndev=256, nd=16, nm=16,
                  measure=False)
        wall = run(dict(base, B=args.measure_batch), v, ndev=8, nd=2, nm=4,
                   measure=True)
        results[v] = {"analysis": ana, "wall": wall}
        print(f"conv_roofline/{args.layer}/{v},"
              f"{wall['wall_s']*1e6:.0f},{wall['wall_prepared_s']*1e6:.0f},"
              f"{ana['coll_bytes_dev']:.3e}")
        saved = ana["coll_bytes_dev"] - ana["coll_bytes_dev_prepared"]
        print(f"#   prepared amortizes {saved:.3e} collective bytes/dev "
              f"(stage-2 transform + its boundary movement)")
    if {"nfft_ep_fused", "nfft_ep_unfused"} <= results.keys():
        fu = results["nfft_ep_fused"]
        un = results["nfft_ep_unfused"]
        extra = (un["analysis"]["coll_bytes_dev"]
                 - fu["analysis"]["coll_bytes_dev"])
        dw = un["wall"]["wall_s"] - fu["wall"]["wall_s"]
        print(f"# epilogue fusion: {extra:.3e} extra collective bytes/dev "
              f"unfused (should be ~0 — the win is elementwise HBM "
              f"traffic), wall delta {dw*1e6:+.0f}us/call")
    if {"nfft", "nfft_overlap2"} <= results.keys():
        sync = results["nfft"]
        ovl = results["nfft_overlap2"]
        extra = (ovl["analysis"]["coll_bytes_dev"]
                 - sync["analysis"]["coll_bytes_dev"])
        dw = sync["wall"]["wall_s"] - ovl["wall"]["wall_s"]
        print(f"# overlap (slab:2 vs synchronous nfft): {extra:+.3e} "
              f"collective bytes/dev (must be ~0 — overlap hides latency, "
              f"it never re-sends), wall delta {dw*1e6:+.0f}us/call "
              f"in favor of overlapped")
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(results, fh, indent=1)


if __name__ == "__main__":
    main()
