"""Paper Table I: the 17 AlexNet/VGG/ResNet unit-stride conv layers.

Measures wall time of the FFT-based convolution vs the direct oracle on
this host (CPU; batch reduced via --batch for tractability) and checks
correctness per layer. The full-size cells are exercised by the dry-run.

CSV: name,us_per_call,derived   (derived = effective GFLOP/s of the
direct-conv FLOP count, i.e. the paper's normalisation)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_convs import TABLE1
from repro.conv import plan_conv
from repro.core import conv2d_direct


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(batch=2, reps=3, layers=None, check=True):
    rows = []
    rng = np.random.default_rng(0)
    for layer in TABLE1:
        if layers and layer.name not in layers:
            continue
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.C, layer.H, layer.W)), jnp.float32)
        k = jnp.asarray(rng.standard_normal(
            (layer.Cout, layer.C, layer.kh, layer.kw)), jnp.float32)
        plan = plan_conv(x.shape, k.shape, padding=layer.pad,
                         backend="fft-xla")
        f_fft = jax.jit(plan)
        f_dir = jax.jit(lambda x, k, p=layer.pad: conv2d_direct(
            x, k, padding=p))
        if check:
            y, y0 = f_fft(x, k), f_dir(x, k)
            err = float(jnp.max(jnp.abs(y - y0))
                        / (jnp.max(jnp.abs(y0)) + 1e-9))
            assert err < 1e-4, (layer.name, err)
        t_fft = _time(f_fft, x, k, reps=reps)
        t_dir = _time(f_dir, x, k, reps=reps)
        spec = plan.spec
        gflops = spec.direct_flops() / 1e9
        rows.append((layer.name, t_fft * 1e6, gflops / t_fft,
                     t_dir * 1e6, t_dir / t_fft))
    return rows


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)
    print("# Table I — name,us_per_call,derived(GFLOP/s)"
          ",direct_us,speedup_vs_direct")
    for name, us, gfps, dus, sp in run(batch=args.batch, reps=args.reps):
        print(f"table1/{name},{us:.0f},{gfps:.2f},{dus:.0f},{sp:.2f}")


if __name__ == "__main__":
    main()
