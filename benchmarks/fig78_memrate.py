"""Paper Figs. 7-8: L2-cache miss-rate comparison, hardware-adapted.

The FT-2000plus PMU events have no TPU (or dry-run host) equivalent. The
quantity the paper actually demonstrates is "nFFT's CGEMM touches only local
memory". The TPU-measurable analogue is the *hot-stage traffic ratio*:

    remote_fraction(strategy) = collective bytes attributable to the CGEMM
                                stage / total bytes the CGEMM stage accesses

computed from the compiled HLO of each stage jitted in isolation on the
8-way host mesh. nFFT's CGEMM should show ~0 collective bytes (pure local),
wFFT's should show the psum of Z.

CSV: name,us_per_call,derived   (derived = wFFT remote fraction - nFFT's)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import make_spec
from repro.core.cgemm import cgemm
from repro.compat import make_mesh, shard_map
from repro.launch.roofline import parse_collectives
mesh = make_mesh((2, 4), ("data", "model"))
spec = json.loads(sys.argv[1])
B, C, Co, H, W, kh, pad = (spec[k] for k in
                           ("B", "C", "Co", "H", "W", "kh", "pad"))
cs = make_spec((B, C, H, W), (Co, C, kh, kh), pad)
n_model = 4
rng = np.random.default_rng(0)


def mk(shape, pspec):
    a = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return jax.device_put(a, NamedSharding(mesh, pspec))


out = {}
# --- nFFT hot stage: P sharded over model, M over data; local einsum ------
Dr = mk((cs.P, cs.M, C), P("model", "data", None))
Di = mk((cs.P, cs.M, C), P("model", "data", None))
Gr = mk((cs.P, C, Co), P("model", None, None))
Gi = mk((cs.P, C, Co), P("model", None, None))
f_n = jax.jit(
    shard_map(lambda a, b, c, d: cgemm(a, b, c, d),
              mesh=mesh,
              in_specs=(P("model", "data", None), P("model", "data", None),
                        P("model", None, None), P("model", None, None)),
              out_specs=(P("model", "data", None),
                         P("model", "data", None))))
# --- wFFT hot stage: C sharded over model -> psum inside ------------------
Dr2 = mk((cs.P, cs.M, C), P(None, "data", "model"))
Di2 = mk((cs.P, cs.M, C), P(None, "data", "model"))
Gr2 = mk((cs.P, C, Co), P(None, "model", None))
Gi2 = mk((cs.P, C, Co), P(None, "model", None))


def wfft_body(a, b, c, d):
    zr, zi = cgemm(a, b, c, d)
    return (jax.lax.psum(zr, "model"), jax.lax.psum(zi, "model"))


f_w = jax.jit(
    shard_map(wfft_body, mesh=mesh,
              in_specs=(P(None, "data", "model"), P(None, "data", "model"),
                        P(None, "model", None), P(None, "model", None)),
              out_specs=(P(None, "data", None), P(None, "data", None))))

for name, f, args in (("nfft", f_n, (Dr, Di, Gr, Gi)),
                      ("wfft", f_w, (Dr2, Di2, Gr2, Gi2))):
    comp = f.lower(*args).compile()
    coll = parse_collectives(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    jax.block_until_ready(f(*args))
    ts = []
    for _ in range(spec["reps"]):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        ts.append(time.perf_counter() - t0)
    out[name] = {"coll_bytes": coll["total_bytes"],
                 "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
                 "t": float(np.median(ts))}
print("RESULT" + json.dumps(out))
"""

LAYERS = [
    ("Vconv4.2", 4, 512, 512, 28, 28, 3, 1),
    ("Aconv3", 8, 256, 384, 13, 13, 3, 1),
    ("Rconv5.2", 8, 512, 512, 7, 7, 3, 1),
]


def run_layer(name, B, C, Co, H, W, kh, pad, reps=3):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    spec = dict(B=B, C=C, Co=Co, H=H, W=W, kh=kh, pad=pad, reps=reps)
    r = subprocess.run([sys.executable, "-c", _WORKER, json.dumps(spec)],
                       env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"{name}: {r.stderr[-2000:]}")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def main(argv=None):
    print("# Fig 7-8 — name,us_per_call(nfft cgemm),derived(remote-frac "
          "delta wfft-nfft),nfft_remote_frac,wfft_remote_frac")
    for (name, *args) in LAYERS:
        res = run_layer(name, *args)
        fr = {}
        for s in ("nfft", "wfft"):
            denom = res[s]["hbm_bytes"] + res[s]["coll_bytes"]
            fr[s] = res[s]["coll_bytes"] / denom if denom else 0.0
        print(f"fig78/{name},{res['nfft']['t']*1e6:.0f},"
              f"{fr['wfft']-fr['nfft']:.3f},{fr['nfft']:.3f},"
              f"{fr['wfft']:.3f}")


if __name__ == "__main__":
    main()
