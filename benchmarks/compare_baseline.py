"""CI perf-regression gate: BENCH_conv.json vs the committed baseline.

    python -m benchmarks.compare_baseline --current BENCH_conv.json \
        [--baseline benchmarks/BENCH_baseline.json] [--tolerance 2.5]

Prints a per-entry delta table and exits non-zero when any shared entry is
slower than ``tolerance`` x its baseline (2.5x by default — wide enough
for shared-runner noise, tight enough to catch a real 10x cliff).  Entries
below ``--min-us`` in the baseline are skipped (pure-jitter territory);
entries that exist on only one side are reported but don't fail unless
``--strict-missing`` (bench sets legitimately grow and shrink — baseline
refresh is ``python -m benchmarks.update_baseline``).
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.bench_schema import load_normalized


def compare(baseline: dict, current: dict, *, tolerance: float,
            min_us: float = 0.0):
    """Returns (rows, regressions, missing, new); rows are
    (name, base_us, cur_us, ratio, status) sorted worst-first."""
    rows, regressions = [], []
    missing = sorted(set(baseline) - set(current))
    new = sorted(set(current) - set(baseline))
    for name in sorted(set(baseline) & set(current)):
        base = baseline[name]["us_per_call"]
        cur = current[name]["us_per_call"]
        if base < min_us:
            rows.append((name, base, cur, None, "skipped (<min-us)"))
            continue
        ratio = cur / base if base > 0 else float("inf")
        status = "ok"
        if ratio > tolerance:
            status = f"REGRESSED (> {tolerance:g}x)"
            regressions.append(name)
        rows.append((name, base, cur, ratio, status))
    rows.sort(key=lambda r: -(r[3] if r[3] is not None else -1.0))
    return rows, regressions, missing, new


def format_table(rows, missing, new) -> str:
    width = max([len(r[0]) for r in rows] + [len(n) for n in missing + new]
                + [4])
    lines = [f"{'name':<{width}}  {'baseline':>12}  {'current':>12}  "
             f"{'ratio':>7}  status"]
    for name, base, cur, ratio, status in rows:
        r = f"{ratio:7.2f}" if ratio is not None else "      -"
        lines.append(f"{name:<{width}}  {base:>10.1f}us  {cur:>10.1f}us  "
                     f"{r}  {status}")
    for name in missing:
        lines.append(f"{name:<{width}}  {'(baseline)':>12}  "
                     f"{'MISSING':>12}  {'':>7}  not in current run")
    for name in new:
        lines.append(f"{name:<{width}}  {'NEW':>12}  {'':>12}  {'':>7}  "
                     "not in baseline (update_baseline to adopt)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_conv.json")
    ap.add_argument("--tolerance", type=float, default=2.5,
                    help="fail when current > tolerance x baseline "
                         "(default 2.5)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="skip entries whose baseline is below this "
                         "(jitter floor)")
    ap.add_argument("--strict-missing", action="store_true",
                    help="also fail when a baseline entry vanished from "
                         "the current run")
    args = ap.parse_args(argv)

    try:
        baseline = load_normalized(args.baseline)
        current = load_normalized(args.current)
    except (OSError, ValueError) as e:
        print(f"perf gate: cannot load inputs: {e}", file=sys.stderr)
        return 2
    if not current:
        print("perf gate: current run produced no entries", file=sys.stderr)
        return 2

    rows, regressions, missing, new = compare(
        baseline, current, tolerance=args.tolerance, min_us=args.min_us)
    print(format_table(rows, missing, new))
    if regressions:
        print(f"\nperf gate FAILED: {len(regressions)} entr"
              f"{'y' if len(regressions) == 1 else 'ies'} regressed "
              f"beyond {args.tolerance:g}x: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    if missing and args.strict_missing:
        print(f"\nperf gate FAILED (--strict-missing): baseline entries "
              f"vanished: {', '.join(missing)}", file=sys.stderr)
        return 1
    print(f"\nperf gate OK: {len(rows)} compared, {len(new)} new, "
          f"{len(missing)} missing, tolerance {args.tolerance:g}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
