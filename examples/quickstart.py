"""Quickstart: the paper's FFT-based convolution behind the plan/execute API.

    PYTHONPATH=src python examples/quickstart.py

``plan_conv`` picks the algorithm (direct vs FFT) from the geometry's cost
model, freezes the schedule, and the returned plan executes (and
differentiates) like a plain function. Plans are cached by shape, so
planning inside a layer loop is free after the first call.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.conv import plan_conv, plan_cache_info
from repro.core import conv2d_direct
from repro.core.fftconv import freq_count

rng = np.random.default_rng(0)

# A VGG-ish layer: 64 -> 128 channels, 56x56, 3x3, unit stride, pad 1.
x = jnp.asarray(rng.standard_normal((2, 64, 56, 56)), jnp.float32)
k = jnp.asarray(rng.standard_normal((128, 64, 3, 3)), jnp.float32)

plan = plan_conv(x.shape, k.shape, padding=1)      # backend="auto"
y_fft = plan(x, k)                                 # execute
y_ref = conv2d_direct(x, k, padding=1)             # direct oracle

err = float(jnp.max(jnp.abs(y_fft - y_ref)) / jnp.max(jnp.abs(y_ref)))
print(f"output {y_fft.shape}, rel err vs direct conv: {err:.2e}")
print(plan.describe())

spec = plan.spec
print(f"tiling: {spec.X}x{spec.D} tiles of {spec.delta}x{spec.delta}, "
      f"P={freq_count(spec, plan.spectrum)} frequency points "
      f"({plan.spectrum} layout), CGEMM {spec.M}x{spec.C}x{spec.Cout}")

# The cost model sends small geometries to the direct backend instead.
tiny = plan_conv((1, 3, 16, 16), (4, 3, 1, 1))
print(f"auto backend for a 1x1-kernel layer: {tiny.backend} "
      f"(vs {plan.backend} for the VGG layer)")

# Plans are differentiable on every backend x schedule (plan-level VJP).
def loss(k):
    return jnp.mean((plan(x, k) - y_ref) ** 2)

g = jax.grad(loss)(k)
print("grad norm through the plan:", float(jnp.linalg.norm(g)))
print("plan cache:", plan_cache_info())

# Serving: prepare once (the kernel transform is cached under a weights
# version), then every call runs stages 1/3/4 only.
prepared = plan.prepare(k, weights_version=0)
y_prep = prepared(x)
print("prepared exec matches one-shot:",
      bool(jnp.allclose(y_prep, y_fft, atol=1e-5)))
