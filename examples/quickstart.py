"""Quickstart: the paper's FFT-based convolution as a drop-in op.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft_conv2d, conv2d_direct, make_spec

rng = np.random.default_rng(0)

# A VGG-ish layer: 64 -> 128 channels, 56x56, 3x3, unit stride, pad 1.
x = jnp.asarray(rng.standard_normal((2, 64, 56, 56)), jnp.float32)
k = jnp.asarray(rng.standard_normal((128, 64, 3, 3)), jnp.float32)

y_fft = fft_conv2d(x, k, padding=1)           # the paper's algorithm
y_ref = conv2d_direct(x, k, padding=1)        # direct oracle

err = float(jnp.max(jnp.abs(y_fft - y_ref)) / jnp.max(jnp.abs(y_ref)))
print(f"output {y_fft.shape}, rel err vs direct conv: {err:.2e}")

spec = make_spec(x.shape, k.shape, padding=1)
print(f"tiling: {spec.X}x{spec.D} tiles of {spec.delta}x{spec.delta}, "
      f"P={spec.P} frequency points, CGEMM {spec.M}x{spec.C}x{spec.Cout}")
print(f"direct FLOPs {spec.direct_flops()/1e9:.2f}G vs "
      f"CGEMM FLOPs {spec.cgemm_flops(three_m=True)/1e9:.2f}G "
      f"+ transforms {spec.transform_flops()/1e9:.2f}G")

# It is differentiable (custom VJP): train through it.
def loss(k):
    return jnp.mean((fft_conv2d(x, k, padding=1) - y_ref) ** 2)

g = jax.grad(loss)(k)
print("grad norm through fft_conv2d:", float(jnp.linalg.norm(g)))
