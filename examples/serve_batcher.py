"""Continuous batching: the shape-bucketed serve engine end to end.

    PYTHONPATH=src python examples/serve_batcher.py

Ragged requests (every client its own batch size) hit a small fixed set
of padded batch buckets, each planned (``plan_network``) + prepared
(``NetworkPlan.prepare``) + jit-compiled ONCE at startup. The drain loop
FIFO-packs the queue into bucket batches, pads, executes, unpads per
request — zero re-planning or re-tracing on the hot path, certified by
the plan-cache miss counter in the report.
"""
import jax.numpy as jnp
import numpy as np

from repro.conv import Epilogue, NetworkConv
from repro.launch.batcher import (
    BucketPolicy, RequestTooLarge, ServeEngine, run_trace,
    synthetic_trace,
)

rng = np.random.default_rng(0)


def init(shape, s=0.05):
    return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)


# A two-layer conv trunk, shaped per bucket batch size.
def make_layers(batch):
    ep = Epilogue(bias=True, activation="relu")
    return [
        NetworkConv("c1", (batch, 8, 32, 32), (16, 8, 3, 3), padding=1,
                    epilogue=ep),
        NetworkConv("c2", (batch, 16, 32, 32), (16, 16, 3, 3), padding=1,
                    epilogue=ep),
    ]


kernels = {"c1": init((16, 8, 3, 3)), "c2": init((16, 16, 3, 3))}
biases = {"c1": init((16,)), "c2": init((16,))}


def forward(prepared, x):
    for name in prepared:
        x = prepared[name](x, bias=biases[name])
    return x


policy = BucketPolicy(max_batch=4)            # buckets (1, 2, 4)
engine = ServeEngine(make_layers, kernels, policy=policy,
                     forward=forward, window_s=2e-3)
print(f"buckets: {policy.batch_buckets()} "
      f"(dedupe: {engine.bucket_report()['n_distinct_plans']} distinct "
      f"plans for {engine.bucket_report()['n_layer_plans']} layer slots)")

# Oversize requests are rejected up front, not padded into oblivion.
try:
    engine.submit(jnp.zeros((9, 8, 32, 32), jnp.float32))
except RequestTooLarge as e:
    print(f"rejected: {e}")

# Replay a ragged Poisson trace (burst mode: deterministic backlog).
trace = synthetic_trace(n_requests=16, max_batch=4, rate_rps=50.0, seed=0)
rep = run_trace(engine, trace, realtime=False,
                make_input=lambda b, img: init((b, 8, 32, 32), 1.0))

print(f"served {rep['n_requests']} requests in {rep['wall_s']:.3f}s "
      f"({rep['throughput_rows_s']:.0f} rows/s), "
      f"p50={rep['p50_us'] / 1e3:.1f}ms p99={rep['p99_us'] / 1e3:.1f}ms")
for label, b in sorted(rep["buckets"].items()):
    print(f"  {label}: {b['n_requests']} requests in {b['n_batches']} "
          f"batches, occupancy {b['occupancy']:.2f}")
assert rep["plan_cache_misses_after_warmup"] == 0   # hot path never plans

# A weight update is ONE invalidation sweep across every bucket.
engine.update_weights({k: v * 2.0 for k, v in kernels.items()},
                      weights_version=1)
rid = engine.submit(init((3, 8, 32, 32), 1.0))
engine.drain(force=True)
print(f"after weight update: result {tuple(engine.results[rid].shape)} "
      f"(request rows preserved through pad/unpad)")
