"""End-to-end driver #2: LM pretraining via the launcher (any --arch).

Reduced configs run on CPU; the full configs are what the multi-pod dry-run
lowers. Checkpointing/resume and the straggler watchdog are exercised here.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b \
        --steps 200 --batch 8 --seq 128
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if "--smoke" not in args:
        args.append("--smoke")
    main(args)
