"""End-to-end driver #3: batched serving (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b \
        --batch 4 --prompt-len 32 --gen 16
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if "--smoke" not in args:
        args.append("--smoke")
    main(args)
