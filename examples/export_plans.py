"""Plan artifacts: build once, deploy many (fleet cold-start).

    PYTHONPATH=src python examples/export_plans.py

One builder worker pays the full plan lifecycle — plan every layer,
transform every kernel, trace + compile every jit — and exports the
result as a single ``.rpa`` artifact (``NetworkPlan.export``).  Every
other worker in the fleet then rehydrates a runnable network from the
file (``load_network``): zero re-planning, zero re-tracing, and on an
identical worker zero re-compiling (the artifact ships the XLA
executables themselves).  An incompatible worker — other jax version,
other device kind — falls back to live planning from the stored configs
and kernels, with a warning, so a mixed fleet still comes up.
"""
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.conv import (
    Epilogue, NetworkConv, load_network, plan_network,
)
from repro.conv.export import read_manifest, verify

rng = np.random.default_rng(0)


def init(shape, s=0.05):
    return jnp.asarray(s * rng.standard_normal(shape), jnp.float32)


layers = [
    NetworkConv("c1", (4, 8, 32, 32), (16, 8, 3, 3), padding=1,
                epilogue=Epilogue(bias=True, activation="relu")),
    NetworkConv("c2", (4, 16, 32, 32), (16, 16, 3, 3), padding=1),
]
kernels = {"c1": init((16, 8, 3, 3)), "c2": init((16, 16, 3, 3))}
bias = init((16,))
x = init((4, 8, 32, 32), 1.0)

path = os.path.join(tempfile.mkdtemp(), "trunk.rpa")

# ---- builder worker: plan + prepare + export ---------------------------
t0 = time.perf_counter()
net = plan_network(layers, backend="fft-xla")
prepared = net.prepare(kernels, weights_version=7)
y_live = prepared["c2"](prepared["c1"](x, bias=bias))
net.export(path, params=kernels, weights_version=7)
print(f"built + exported in {time.perf_counter() - t0:.2f}s "
      f"-> {path} ({os.path.getsize(path) / 1e6:.2f} MB)")

man = read_manifest(path)
print(f"artifact: jax {man['jax_version']}, device {man['device_kind']}, "
      f"weights_version {man['weights_version']}, "
      f"{len(man['nets']['net']['layers'])} layers")

# ---- fleet worker: rehydrate, no planning ------------------------------
t0 = time.perf_counter()
loaded = load_network(path)           # same process stands in for a
t_load = time.perf_counter() - t0     # fresh worker; see tests for the
print(f"rehydrated in {t_load:.2f}s "  # true subprocess cold-start
      f"(source={loaded.source}, native="
      f"{all(lc.native for lc in loaded.layers.values())})")

y_aot = loaded["c2"](loaded["c1"](x, bias=bias))
err = float(jnp.max(jnp.abs(y_aot - y_live)))
print(f"parity vs live-planned: max |diff| = {err:.2e}")
assert err < 1e-5

# ---- certification: stored fingerprints vs a live re-plan --------------
v = verify(path)
print(f"verify: ok={v['ok']} ({v['n_checked']} layer fingerprints "
      "match a live re-plan)")
assert v["ok"]
