"""End-to-end driver #1: train a small CNN whose conv layers run through
the paper's FFT-based convolution with the bias+ReLU epilogue FUSED into
the pipeline (stage 4), via the plan/execute API — then evaluate through a
*network plan*: every layer resolved in one pass, every kernel transform
prepared once per weights version.

    PYTHONPATH=src python examples/train_cnn_fftconv.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.conv import (
    Epilogue, NetworkConv, plan_network, prepared_cache_info,
)
from repro.data import DataConfig, image_batch
from repro.models.layers import conv_block, maxpool2x2
from repro.optim import AdamWConfig, adamw_init, adamw_update


def init_params(key):
    ks = jax.random.split(key, 4)
    init = lambda k, s: 0.1 * jax.random.normal(k, s, jnp.float32)
    return {
        "c1": init(ks[0], (16, 3, 3, 3)),
        "b1": jnp.zeros((16,), jnp.float32),
        "c2": init(ks[1], (32, 16, 3, 3)),
        "b2": jnp.zeros((32,), jnp.float32),
        "w": init(ks[2], (32 * 8 * 8, 10)),
        "b": jnp.zeros((10,), jnp.float32),
    }


def forward(p, x):
    # conv + bias + relu is ONE fused plan per layer: the epilogue runs
    # inside the pipeline (stage 4), and the plan-level VJP differentiates
    # x, k AND bias through the fusion.
    h = conv_block(x, p["c1"], p["b1"], activation="relu",
                   padding=1, backend="fft-xla")                # 32x32
    h = maxpool2x2(h)
    h = conv_block(h, p["c2"], p["b2"], activation="relu",
                   padding=1, backend="fft-xla")                # 16x16
    h = maxpool2x2(h)
    h = h.reshape(h.shape[0], -1)                               # 8x8x32
    return h @ p["w"] + p["b"]


def eval_network(batch):
    """The serving-side view of the same net: resolve both conv layers in
    ONE planning pass (shared plan cache) with their fused epilogues."""
    ep = Epilogue(bias=True, activation="relu")
    return plan_network([
        NetworkConv("c1", (batch, 3, 32, 32), (16, 3, 3, 3), padding=1,
                    epilogue=ep),
        NetworkConv("c2", (batch, 16, 16, 16), (32, 16, 3, 3), padding=1,
                    epilogue=ep),
    ], backend="fft-xla")


def forward_prepared(p, prepared, x):
    h = maxpool2x2(prepared["c1"](x, bias=p["b1"]))
    h = maxpool2x2(prepared["c2"](h, bias=p["b2"]))
    return h.reshape(h.shape[0], -1) @ p["w"] + p["b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    params = init_params(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps,
                          weight_decay=0.0)
    opt = adamw_init(params)
    dc = DataConfig(vocab=0, seq_len=0, global_batch=args.batch, seed=0,
                    kind="images")

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = forward(p, x)
            onehot = jax.nn.one_hot(y, 10)
            return -jnp.mean(jnp.sum(
                jax.nn.log_softmax(logits) * onehot, -1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    for i in range(args.steps):
        b = image_batch(dc, i)
        params, opt, loss = step(params, opt, b["images"], b["labels"])
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f}")
    # biases learned THROUGH the fused epilogue (d_bias comes out of the
    # plan-level VJP, not a separate op's grad)
    assert float(jnp.max(jnp.abs(params["b1"]))) > 0, \
        "bias never updated — fused-epilogue bias grad is broken"

    # Eval through the network plan: both layers resolved in one pass and
    # prepared once (keyed by the final step as weights_version); every
    # eval batch skips stage 2 and runs the fused epilogue on the slab.
    net = eval_network(args.batch)
    prepared = net.prepare({"c1": params["c1"], "c2": params["c2"]},
                               weights_version=args.steps)
    b = image_batch(dc, 10_000)
    logits = forward_prepared(params, prepared, b["images"])
    acc = float(jnp.mean(jnp.argmax(logits, -1) == b["labels"]))
    # second sweep under the same version: pure prepared-cache hits
    net.prepare({"c1": params["c1"], "c2": params["c2"]},
                    weights_version=args.steps)
    info = prepared_cache_info()
    print(f"held-out acc {acc:.2f} ({time.time()-t0:.1f}s) — trained via "
          "the plan-level VJP through fused epilogues, served via "
          f"plan_network (prepared cache: {info.hits} hits / "
          f"{info.misses} misses)")
    assert info.hits >= 2, "re-preparing same version should hit the cache"
    assert float(loss) < 2.5, "training through FFT conv failed to learn"


if __name__ == "__main__":
    main()
