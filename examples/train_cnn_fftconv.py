"""End-to-end driver #1: train a small CNN whose conv layers run through
the paper's FFT-based convolution (plan-level VJP) via the plan/execute
API, on synthetic images — then evaluate through *prepared* plans (the
kernel transforms of the trained weights are cached once and reused).

    PYTHONPATH=src python examples/train_cnn_fftconv.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.conv import prepared_cache_info
from repro.data import DataConfig, image_batch
from repro.models.layers import conv2d_planned
from repro.optim import AdamWConfig, adamw_init, adamw_update


def init_params(key):
    ks = jax.random.split(key, 4)
    init = lambda k, s: 0.1 * jax.random.normal(k, s, jnp.float32)
    return {
        "c1": init(ks[0], (16, 3, 3, 3)),
        "c2": init(ks[1], (32, 16, 3, 3)),
        "w": init(ks[2], (32 * 8 * 8, 10)),
        "b": jnp.zeros((10,), jnp.float32),
    }


def _conv(x, k, *, weights_version=None):
    # plan_conv is cached by shape: each layer geometry plans exactly once.
    # During training the plan-level VJP differentiates x AND k; at eval a
    # weights_version routes through a prepared plan (stage 2 cached).
    return conv2d_planned(x, k, padding=1, backend="fft-xla",
                          weights_version=weights_version)


def forward(p, x, *, weights_version=None):
    h = jax.nn.relu(_conv(x, p["c1"],
                          weights_version=weights_version))     # 32x32
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    h = jax.nn.relu(_conv(h, p["c2"],
                          weights_version=weights_version))     # 16x16
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    h = h.reshape(h.shape[0], -1)                               # 8x8x32
    return h @ p["w"] + p["b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    params = init_params(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps,
                          weight_decay=0.0)
    opt = adamw_init(params)
    dc = DataConfig(vocab=0, seq_len=0, global_batch=args.batch, seed=0,
                    kind="images")

    @jax.jit
    def step(params, opt, x, y):
        def loss_fn(p):
            logits = forward(p, x)
            onehot = jax.nn.one_hot(y, 10)
            return -jnp.mean(jnp.sum(
                jax.nn.log_softmax(logits) * onehot, -1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    for i in range(args.steps):
        b = image_batch(dc, i)
        params, opt, loss = step(params, opt, b["images"], b["labels"])
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f}")
    # Eval through prepared plans: the trained kernels' transforms are
    # computed once (keyed by the final step as weights_version) and every
    # eval batch skips stage 2.
    b = image_batch(dc, 10_000)
    logits = forward(params, b["images"], weights_version=args.steps)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == b["labels"]))
    forward(params, b["images"], weights_version=args.steps)  # cache hits
    info = prepared_cache_info()
    print(f"held-out acc {acc:.2f} ({time.time()-t0:.1f}s) — trained via "
          "the plan-level VJP, evaluated via prepared plans "
          f"(prepared cache: {info.hits} hits / {info.misses} misses)")
    assert info.hits >= 2, "second eval pass should reuse prepared kernels"
    assert float(loss) < 2.5, "training through FFT conv failed to learn"


if __name__ == "__main__":
    main()
