"""Prepare/execute split: cached kernel transforms, stage-2 amortization
(certified via the static analyzer), and weights-version invalidation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import (
    analyze, plan_conv, clear_prepared_cache, prepared_cache_info,
    stage_trace,
)
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


BACKENDS = ["direct", "fft-xla", "fft-pallas"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_prepared_matches_one_shot_local(backend):
    x, k = _rand((2, 3, 18, 18), 1), _rand((4, 3, 3, 3), 2)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend)
    prepared = plan.prepare(k)
    assert prepared.out_shape == plan.out_shape
    np.testing.assert_allclose(np.asarray(prepared(x)),
                               np.asarray(plan(x, k)), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(prepared(x)),
                               np.asarray(conv2d_direct(x, k, padding=1)),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("schedule", ["nfft", "wfft"])
def test_prepared_matches_one_shot_sharded(schedule):
    mesh = make_mesh((1, 1), ("data", "model"))
    x, k = _rand((2, 3, 18, 18), 3), _rand((4, 3, 3, 3), 4)
    plan = plan_conv(x.shape, k.shape, padding=1, schedule=schedule,
                     mesh=mesh)
    prepared = plan.prepare(k)
    np.testing.assert_allclose(
        np.asarray(prepared(x)),
        np.asarray(conv2d_direct(x, k, padding=1)), rtol=3e-4, atol=3e-4)
    # prepared execution works under jit too
    np.testing.assert_allclose(np.asarray(jax.jit(prepared)(x)),
                               np.asarray(prepared(x)), rtol=1e-6, atol=1e-6)


def test_prepared_nfft_skips_stage2_and_boundary_a2a2():
    """The acceptance check: a prepared nfft execution must trace ZERO
    kernel-transform stages and one fewer all_to_all boundary (re/im pair)
    than the one-shot plan — stage 2 and boundary a2a #2 are amortized.
    Counts come from the static analyzer walking the traced equation tree
    (no pretty-printer string matching)."""
    mesh = make_mesh((1, 1), ("data", "model"))
    x, k = _rand((2, 4, 20, 20), 5), _rand((4, 4, 3, 3), 6)
    plan = plan_conv(x.shape, k.shape, padding=1, schedule="nfft", mesh=mesh)
    prep = analyze(plan.prepare(k))
    full = analyze(plan)

    assert prep.stage_counts.get("kernel_transform", 0) == 0
    assert full.stage_counts["kernel_transform"] == 1
    assert prep.stage_counts["boundary_a2a"] == 2  # a2a #1 and #3 only
    assert full.stage_counts["boundary_a2a"] == 3
    # the traced program agrees: 4 all_to_all eqns (2 boundaries x re/im)
    # vs 6 for the one-shot path, and the elision is exactly one a2a pair
    # plus the kernel transform
    assert prep.collectives["all_to_all"] == 4
    assert full.collectives["all_to_all"] == 6
    assert prep.elision == {"all_to_all": 2, "psum": 0, "ppermute": 0,
                            "all_gather": 0, "kernel_transform": 1}
    # and both variants satisfy the registered invariants
    assert prep.check().ok and full.check().ok


def test_prepare_runs_stage2_eagerly_not_per_execute():
    x, k = _rand((1, 2, 12, 12), 7), _rand((2, 2, 3, 3), 8)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")
    with stage_trace() as prep_counts:
        prepared = plan.prepare(k)
    assert prep_counts["kernel_transform"] == 1
    with stage_trace() as exec_counts:
        prepared(x)
        prepared(x)
    assert exec_counts.get("kernel_transform", 0) == 0


def test_weights_version_invalidation():
    """Same (kernel, version) -> cache hit; bumped version -> the cached
    transform is invalidated and recomputed; numerics always track the
    weights actually passed."""
    clear_prepared_cache()
    x = _rand((2, 3, 16, 16), 9)
    k1, k2 = _rand((4, 3, 3, 3), 10), _rand((4, 3, 3, 3), 11)
    plan = plan_conv(x.shape, k1.shape, padding=1, backend="fft-xla")

    p1 = plan.prepare(k1, weights_version=1)
    assert prepared_cache_info().misses == 1
    np.testing.assert_allclose(np.asarray(p1(x)),
                               np.asarray(conv2d_direct(x, k1, padding=1)),
                               rtol=3e-4, atol=3e-4)
    # same kernel + same version: memoized object, no recompute
    assert plan.prepare(k1, weights_version=1) is p1
    assert prepared_cache_info().hits == 1

    # weight update -> same kernel slot, new version: invalidation fires
    # and the numerics follow the new weights
    p2 = plan.prepare(k2, weights_version=2)
    assert p2 is not p1
    np.testing.assert_allclose(np.asarray(p2(x)),
                               np.asarray(conv2d_direct(x, k2, padding=1)),
                               rtol=3e-4, atol=3e-4)
    p1b = plan.prepare(k1, weights_version=2)
    assert p1b is not p1
    assert prepared_cache_info().invalidations == 1   # k1's entry replaced
    # version=None is never cached
    size = prepared_cache_info().size
    assert plan.prepare(k1) is not p1b
    assert prepared_cache_info().size == size
    clear_prepared_cache()


def test_same_geometry_layers_do_not_collide():
    """Regression: two layers with identical geometry share one ConvPlan;
    preparing both under the same weights_version must NOT hand layer B
    layer A's cached transform (the cache is keyed per kernel)."""
    clear_prepared_cache()
    x = _rand((1, 3, 16, 16), 17)
    kA, kB = _rand((4, 3, 3, 3), 18), _rand((4, 3, 3, 3), 19)
    planA = plan_conv(x.shape, kA.shape, padding=1, backend="fft-xla")
    planB = plan_conv(x.shape, kB.shape, padding=1, backend="fft-xla")
    assert planA is planB                   # shared plan (the trap)
    yA = planA.prepare(kA, weights_version=7)(x)
    yB = planB.prepare(kB, weights_version=7)(x)
    np.testing.assert_allclose(np.asarray(yA),
                               np.asarray(conv2d_direct(x, kA, padding=1)),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(yB),
                               np.asarray(conv2d_direct(x, kB, padding=1)),
                               rtol=3e-4, atol=3e-4)
    assert prepared_cache_info().size == 2
    clear_prepared_cache()


def test_prepared_rejects_mismatched_shapes():
    plan = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=1,
                     backend="fft-xla")
    x, k = _rand((2, 3, 16, 16), 12), _rand((4, 3, 3, 3), 13)
    with pytest.raises(ValueError, match="plan was built for kernel"):
        plan.prepare(k[:2])
    prepared = plan.prepare(k)
    with pytest.raises(ValueError, match="plan was built for input"):
        prepared(x[:1])


@pytest.mark.parametrize("backend", ["fft-xla", "fft-pallas"])
def test_prepared_differentiable_wrt_input(backend):
    """Prepared execution carries the plan-level VJP for x (the kernel is
    frozen by design) — including fft-pallas, whose kernel jax cannot
    differentiate through natively."""
    x, k = _rand((1, 2, 12, 12), 14), _rand((3, 2, 3, 3), 15)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend)
    prepared = plan.prepare(k)
    g1 = jax.grad(lambda a: jnp.sum(jnp.sin(prepared(a))))(x)
    g0 = jax.grad(lambda a: jnp.sum(jnp.sin(
        conv2d_direct(a, k, padding=1))))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0),
                               rtol=3e-4, atol=3e-4)


def test_prepared_cache_is_lru_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_CONV_PLAN_CACHE_SIZE", "2")
    clear_prepared_cache()
    k = _rand((2, 2, 3, 3), 16)
    plans = [plan_conv((1, 2, 8 + i, 8), (2, 2, 3, 3), padding=1,
                       backend="fft-xla") for i in range(3)]
    for plan in plans:
        plan.prepare(k, weights_version=0)
    assert prepared_cache_info().size == 2      # oldest evicted
    clear_prepared_cache()
