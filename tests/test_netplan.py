"""Network-level planning: one resolution pass against the shared plan
cache, ``NetworkPlan.prepare`` running each layer's kernel transform
exactly once per weights_version, and the aggregate stage/collective
report."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.conv import (
    Epilogue, NetworkConv, clear_plan_cache, clear_prepared_cache,
    plan_cache_info, plan_network, prepared_cache_info, stage_trace,
)
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


EP = Epilogue(bias=True, activation="relu")


def _layers(batch=2):
    return [
        NetworkConv("c1", (batch, 3, 16, 16), (8, 3, 3, 3), padding=1,
                    epilogue=EP),
        NetworkConv("c2", (batch, 8, 16, 16), (8, 8, 3, 3), padding=1,
                    epilogue=EP),
        NetworkConv("c3", (batch, 8, 16, 16), (8, 8, 3, 3), padding=1,
                    epilogue=EP),
    ]


def test_plan_network_resolves_through_shared_cache():
    clear_plan_cache()
    net = plan_network(_layers(), backend="fft-xla")
    assert net.layer_names == ("c1", "c2", "c3")
    # same-geometry layers share ONE frozen plan (cache dedupe)
    assert net["c2"] is net["c3"]
    assert net["c1"] is not net["c2"]
    misses_after_first = plan_cache_info().misses
    # re-planning the network is pure cache hits
    net2 = plan_network(_layers(), backend="fft-xla")
    assert all(net2[n] is net[n] for n in net)
    assert plan_cache_info().misses == misses_after_first


def test_prepare_transforms_once_per_layer_per_version():
    """Acceptance: a multi-layer eval runs the kernel transform exactly
    once per layer per weights_version."""
    clear_prepared_cache()
    net = plan_network(_layers(), backend="fft-xla")
    params = {n: _rand(net[n].k_shape, i) for i, n in enumerate(net)}
    biases = {n: _rand((net[n].spec.Cout,), 10 + i)
              for i, n in enumerate(net)}
    x = _rand((2, 3, 16, 16), 20)

    with stage_trace() as c:
        prepared = net.prepare(params, weights_version=1)
    assert c["kernel_transform"] == len(net)        # once per layer...

    def fwd(prepared, x):
        h = x
        for n in net.layer_names:
            h = prepared[n](h, bias=biases[n])
        return h

    with stage_trace() as c:
        y = fwd(prepared, x)
        fwd(prepared, x)                            # ...and never at eval
        net.prepare(params, weights_version=1)      # same version: hits
    assert c.get("kernel_transform", 0) == 0
    assert prepared_cache_info().hits >= len(net)

    # numerics: chained prepared+fused layers vs the direct oracle chain
    h0 = x
    for n in net.layer_names:
        h0 = jnp.maximum(conv2d_direct(h0, params[n], padding=1)
                         + biases[n][None, :, None, None], 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(h0),
                               rtol=3e-4, atol=3e-4)

    # weight update -> ONE sweep re-transforming every layer
    params2 = {n: k + 0.1 for n, k in params.items()}
    with stage_trace() as c:
        prepared2 = net.prepare(params2, weights_version=2)
    assert c["kernel_transform"] == len(net)
    y2 = fwd(prepared2, x)
    assert not np.allclose(np.asarray(y), np.asarray(y2))
    clear_prepared_cache()


def test_report_aggregates_stages_and_collectives():
    net = plan_network(_layers(), backend="fft-xla")
    rep = net.report()
    assert rep["n_layers"] == 3
    assert rep["n_distinct_plans"] == 2
    assert rep["total_stage_counts"]["cgemm"] == 3
    assert rep["total_stage_counts"]["kernel_transform"] == 3
    assert rep["total_collectives"]["all_to_all"] == 0   # local schedule
    assert rep["total_flops"] == sum(p.flops()
                                     for p in net.plans.values())
    assert "c1" in net.describe()


def test_report_counts_sharded_collectives():
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    layers = [NetworkConv("s1", (2, 4, 16, 16), (4, 4, 3, 3), padding=1,
                          epilogue=EP)]
    net = plan_network(layers, backend="fft-xla", schedule="nfft",
                       mesh=mesh)
    rep = net.report()
    # 3 boundary a2a x re/im = 6 all_to_all eqns for one nfft layer
    assert rep["total_collectives"]["all_to_all"] == 6
    netw = plan_network(layers, backend="fft-xla", schedule="wfft",
                        mesh=mesh)
    repw = netw.report()
    assert repw["total_collectives"]["psum"] >= 2    # hot-stage re/im pair
    assert repw["total_collectives"]["all_to_all"] == 0


def test_per_layer_overrides_and_errors():
    layers = _layers()
    tiny = NetworkConv("c0", (2, 3, 16, 16), (8, 3, 3, 3), padding=1,
                       epilogue=EP, overrides=(("backend", "direct"),))
    net = plan_network([tiny] + layers, backend="fft-xla")
    assert net["c0"].backend == "direct"
    assert net["c1"].backend == "fft-xla"

    with pytest.raises(ValueError, match="duplicate layer names"):
        plan_network(layers + [layers[0]])
    net2 = plan_network(layers, backend="fft-xla")
    with pytest.raises(ValueError, match="missing kernels"):
        net2.prepare({"c1": _rand((8, 3, 3, 3))}, weights_version=0)


def test_vgg_network_config():
    """The Table-I VGG trunk resolves as one network with fused epilogues."""
    from repro.configs.paper_convs import vgg_network
    layers = vgg_network(2)
    assert [l.name for l in layers][:2] == ["Vconv1.1", "Vconv1.2"]
    assert all(l.epilogue == Epilogue(bias=True, activation="relu")
               for l in layers)
    net = plan_network(layers, backend="fft-xla")
    assert len(net) == 9
    assert net["Vconv1.1"].x_shape == (2, 3, 224, 224)
