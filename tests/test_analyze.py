"""Static analyzer (plan-lint) acceptance: every registered
backend x schedule pair certified against the invariant registry (full,
prepared and fused-epilogue variants), dtype-flow facts, prepared-plan
elision, network-wide aggregation, the seeded-violation negative path
(the gate must FAIL when a pipeline is deliberately broken), and the
``python -m repro.conv.analyze`` CLI exit codes."""
import json

import jax.numpy as jnp
import pytest

from repro.compat import make_mesh
from repro.conv import (
    Epilogue, NetworkConv, PlanProfile, analyze, backend_schedule_pairs,
    invariants_for, plan_conv, plan_network, register_invariant,
)
from repro.conv.analyze import (
    _REGISTRY, VIOLATION_MODES, main, seeded_violation,
)

# collected at import time: the builtin pairs only (tests that register
# extra backends run later and must not widen this grid)
PAIRS = backend_schedule_pairs()


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _plan(backend, schedule, **kw):
    mesh = _mesh() if schedule != "local" else None
    return plan_conv((2, 3, 18, 18), (4, 3, 3, 3), padding=1,
                     backend=backend, schedule=schedule, mesh=mesh, **kw)


# --------------------------------------------------------------------------
# Every registered pair certifies, in every variant
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["full", "prepared", "epilogue"])
@pytest.mark.parametrize("backend,schedule", PAIRS,
                         ids=[f"{b}-{s}" for b, s in PAIRS])
def test_every_pair_certifies(backend, schedule, variant):
    kw = {}
    if variant == "epilogue":
        kw["epilogue"] = Epilogue(bias=True, activation="relu")
    plan = _plan(backend, schedule, **kw)
    profile = analyze(plan, prepared=(variant == "prepared"))
    assert isinstance(profile, PlanProfile)
    profile.check().raise_if_failed()
    assert profile.n_eqns > 0
    assert profile.peak_live_bytes > 0
    if variant == "prepared":
        assert profile.prepared
        assert profile.elision is not None
    else:
        assert not profile.prepared
    if variant == "epilogue":
        assert profile.epilogue_delta is not None
    else:
        assert profile.epilogue_delta is None


def test_analyze_existing_prepared_conv():
    """analyze(PreparedConv) profiles the already-bound prepared state."""
    import numpy as np
    plan = _plan("fft-xla", "nfft")
    k = jnp.asarray(np.random.default_rng(0).standard_normal(plan.k_shape),
                    jnp.float32)
    profile = analyze(plan.prepare(k))
    assert profile.prepared
    assert profile.collectives["all_to_all"] == 4
    assert profile.stage_counts.get("kernel_transform", 0) == 0
    profile.check().raise_if_failed()


def test_analyze_rejects_non_plans():
    with pytest.raises(TypeError, match="ConvPlan"):
        analyze(object())


# --------------------------------------------------------------------------
# Collective / dtype-flow facts (the paper's structural claims)
# --------------------------------------------------------------------------

def test_nfft_collective_and_dtype_facts():
    """nfft pays one a2a pair per live stage boundary; with bf16 compute
    the D and Z boundary pairs (4 eqns) move half-width bytes while the
    kernel boundary stays f32."""
    p32 = analyze(_plan("fft-xla", "nfft"))
    assert p32.collectives == {"all_to_all": 6, "psum": 0, "ppermute": 0,
                               "all_gather": 0}
    p16 = analyze(_plan("fft-xla", "nfft", compute_dtype=jnp.bfloat16))
    assert p16.compute_dtype == "bfloat16"
    assert p16.cgemm_dtypes == ("bfloat16",)
    assert p16.collective_dtypes["all_to_all"] == {"bfloat16": 4,
                                                   "float32": 2}
    assert p16.collective_bytes < p32.collective_bytes  # casts shrink bytes
    assert not p16.has_f64 and not p32.has_f64
    p16.check().raise_if_failed()


def test_wfft_hot_psum_pair_in_compute_dtype():
    p = analyze(_plan("fft-pallas", "wfft", compute_dtype=jnp.bfloat16))
    assert p.collectives == {"all_to_all": 0, "psum": 2, "ppermute": 0,
                             "all_gather": 0}
    assert p.collective_dtypes["psum"] == {"bfloat16": 2}
    assert p.cgemm_dtypes == ("bfloat16",)
    p.check().raise_if_failed()


def test_prepared_and_replicated_elide_kernel_boundary():
    prep = analyze(_plan("fft-xla", "nfft"), prepared=True)
    assert prep.collectives["all_to_all"] == 4
    assert prep.elision == {"all_to_all": 2, "psum": 0, "ppermute": 0,
                            "all_gather": 0, "kernel_transform": 1}
    repl = analyze(_plan("fft-xla", "nfft",
                         replicate_kernel_transform=True))
    assert repl.collectives["all_to_all"] == 4
    repl.check().raise_if_failed()


def test_epilogue_delta_is_zero_everywhere():
    ep = Epilogue(bias=True, activation="silu", residual=True)
    p = analyze(_plan("fft-xla", "wfft", epilogue=ep))
    assert p.epilogue == ep.describe()
    assert all(v == 0 for v in p.epilogue_delta["collectives"].values())
    assert all(v == 0 for v in p.epilogue_delta["stage_counts"].values())


# --------------------------------------------------------------------------
# Invariant registry: wildcards, extension, custom rules
# --------------------------------------------------------------------------

def test_register_invariant_wildcard_merge():
    inv = register_invariant(
        "fft-xla", "local", "test-eqn-budget",
        lambda p: None if p.n_eqns < 10 ** 6 else "program too large",
        "session-local test rule")
    try:
        names = [i.name for i in invariants_for("fft-xla", "local")]
        assert "test-eqn-budget" in names
        assert "no-f64" in names                       # ("*", "*") merged in
        assert "test-eqn-budget" not in [
            i.name for i in invariants_for("fft-pallas", "local")]
        report = analyze(_plan("fft-xla", "local")).check()
        assert "test-eqn-budget" in report.checked
        assert report.ok
    finally:
        _REGISTRY[("fft-xla", "local")].remove(inv)


def test_check_extra_rules_and_failure_raises():
    from repro.conv.analyze import Invariant
    p = analyze(_plan("fft-xla", "local"))
    bad = Invariant("always-fails", lambda p: "boom")
    report = p.check(extra=[bad])
    assert not report.ok
    assert report.violations[0].invariant == "always-fails"
    with pytest.raises(AssertionError, match=r"(?s)plan-lint: .*always-fails"):
        report.raise_if_failed()


# --------------------------------------------------------------------------
# Network-wide aggregation
# --------------------------------------------------------------------------

def test_network_profile_aggregates_and_certifies():
    net = plan_network(
        [NetworkConv("c1", (2, 3, 18, 18), (4, 3, 3, 3), padding=1),
         NetworkConv("c2", (2, 4, 18, 18), (4, 4, 3, 3), padding=1,
                     epilogue=Epilogue(bias=True, activation="relu"))],
        backend="fft-xla", schedule="nfft", mesh=_mesh())
    prof = net.analyze()
    assert list(prof.layers) == ["c1", "c2"]
    assert prof.total_collectives["all_to_all"] == sum(
        p.collectives["all_to_all"] for p in prof.layers.values()) == 12
    assert prof.peak_live_bytes == max(
        p.peak_live_bytes for p in prof.layers.values())
    assert prof.check() == []
    assert prof.raise_if_failed() is prof
    d = prof.to_dict()
    assert set(d["layers"]) == {"c1", "c2"}
    json.dumps(d)                                  # artifact-serializable
    # analyze() dispatches NetworkPlan to the same path
    assert list(analyze(net).layers) == ["c1", "c2"]


# --------------------------------------------------------------------------
# Negative path: a deliberately broken pipeline MUST be caught
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", VIOLATION_MODES)
def test_seeded_violation_is_caught(mode):
    kw = {"compute_dtype": jnp.bfloat16} if mode == "skip-cast" else {}
    if mode == "overlap-oversend":
        kw["overlap"] = "slab:2"       # only overlapped plans hit the slab ops
    with seeded_violation(mode):
        p = analyze(plan_conv((2, 4, 22, 22), (4, 4, 3, 3), padding=1,
                              backend="fft-xla", schedule="nfft",
                              mesh=_mesh(), **kw))
    report = p.check()
    assert not report.ok
    with pytest.raises(AssertionError, match="plan-lint"):
        report.raise_if_failed()


def test_seeded_violation_unknown_mode_and_restore():
    from repro.conv import stages
    orig = stages._boundary_a2a
    with seeded_violation("extra-collective"):
        assert stages._boundary_a2a is not orig
    assert stages._boundary_a2a is orig            # restored on exit
    with pytest.raises(ValueError, match="unknown violation mode"):
        with seeded_violation("nope"):
            pass                                   # pragma: no cover


# --------------------------------------------------------------------------
# CLI gate (the CI entry point)
# --------------------------------------------------------------------------

def test_cli_check_passes_and_writes_json(tmp_path, capsys):
    out = tmp_path / "profiles.json"
    rc = main(["--check", "--limit", "1", "--batch", "2",
               "--json-out", str(out)])
    assert rc == 0
    assert "plan-lint: OK" in capsys.readouterr().out
    payload = json.loads(out.read_text())
    assert payload
    sample = payload[next(iter(payload))]
    for field in ("collectives", "stage_counts", "peak_live_bytes",
                  "cgemm_dtypes"):
        assert field in sample


def test_cli_seeded_violation_fails_the_gate(capsys):
    """Acceptance: the gate exits non-zero when an invariant is broken."""
    rc = main(["--check", "--limit", "1", "--batch", "2",
               "--inject", "extra-collective"])
    assert rc == 1
    assert "VIOLATION" in capsys.readouterr().out


def test_cli_without_action_exits_2(capsys):
    assert main([]) == 2


# --------------------------------------------------------------------------
# Canary: THE one retained string-based jaxpr check
# --------------------------------------------------------------------------

def test_string_canary_agrees_with_analyzer():
    """Deliberately kept string-based (the only such test left): if jax's
    pretty printer ever stops agreeing with the structural equation walk,
    this fails loudly and the analyzer needs a look.  Every other count
    assertion in the suite goes through ``repro.conv.analyze``."""
    import jax
    plan = _plan("fft-xla", "nfft")
    profile = analyze(plan)
    jaxpr = str(jax.make_jaxpr(lambda x, k: plan(x, k))(
        jnp.zeros(plan.x_shape, jnp.float32),
        jnp.zeros(plan.k_shape, jnp.float32)))
    assert jaxpr.count("all_to_all") \
        == profile.collectives["all_to_all"] == 6
