"""Launch-layer consistency: sharding trees must match struct trees for
every (arch x shape) cell — catches spec/struct drift without compiling."""
import jax
import pytest

from repro.configs import ARCH_NAMES, get_config, LONG_CONTEXT_OK
from repro.models.common import SHAPES
from repro.launch import shardings as SH
from repro.launch import specs as SP
from repro.launch.analytic import analytic_costs


class _FakeMesh:
    """Shape-only stand-in (never touches jax device state)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESHES = [_FakeMesh({"data": 16, "model": 16}),
          _FakeMesh({"pod": 2, "data": 16, "model": 16})]


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_match_struct(arch):
    cfg = get_config(arch)
    pstr = SP.param_structs(cfg)
    for mesh in MESHES:
        specs = SH.param_specs(cfg, pstr, mesh, fsdp=True)
        assert jax.tree.structure(specs) == jax.tree.structure(pstr)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", [s.name for s in SHAPES])
def test_cache_specs_match_struct(arch, shape):
    cfg = get_config(arch)
    cell = [s for s in SHAPES if s.name == shape][0]
    if cell.kind == "train":
        pytest.skip("no cache for train cells")
    if shape == "long_500k" and not LONG_CONTEXT_OK[arch]:
        pytest.skip("documented long-context skip")
    cstr = SP.cache_structs(cfg, cell)
    for mesh in MESHES:
        specs = SH.cache_specs(cfg, cell, mesh)
        assert jax.tree.structure(specs) == jax.tree.structure(cstr), \
            (arch, shape)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", [s.name for s in SHAPES])
def test_analytic_costs_positive(arch, shape):
    cfg = get_config(arch)
    cell = [s for s in SHAPES if s.name == shape][0]
    if shape == "long_500k" and not LONG_CONTEXT_OK[arch]:
        pytest.skip("documented long-context skip")
    c = analytic_costs(cfg, cell)
    assert c["flops"] > 0 and c["bytes"] > 0


def test_dryrun_records_complete():
    """The committed dry-run artifacts must cover all 40 cells x 2 meshes
    with zero failures."""
    import json
    import os
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    recs = {}
    for fn in os.listdir(d):
        if fn.endswith(".json") and "__ring" not in fn:
            r = json.load(open(os.path.join(d, fn)))
            recs[(r["arch"], r["shape"], r["mesh"])] = r["status"]
    for mesh in ("pod256", "pod512"):
        for arch in ARCH_NAMES:
            for s in SHAPES:
                st = recs.get((arch, s.name, mesh))
                assert st in ("ok", "skip"), (arch, s.name, mesh, st)
