"""Unit tests for the layer library: SSD vs naive recurrence, MoE vs dense
reference, flash vs full attention, RoPE/norm properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, requires_hypothesis

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.configs import get_config


# --------------------------------------------------------------------------
# Mamba2 SSD: chunked algorithm vs O(S^2)-free naive recurrence
# --------------------------------------------------------------------------

def _naive_ssm(xh, dt, A, Bm, Cm):
    """h_t = h_{t-1} * exp(dt_t A) + dt_t B_t x_t ; y_t = C_t . h_t."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((Bsz, H, P, N))
    ys = np.zeros((Bsz, S, H, P))
    for t in range(S):
        dec = np.exp(dt[:, t, :, None, None] * A[None, :, None, None])
        upd = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        h = h * dec + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(16, 4), (24, 8), (8, 8)])
def test_ssd_chunked_matches_naive(S, chunk):
    rng = np.random.default_rng(0)
    Bsz, H, P, N = 2, 3, 4, 5
    xh = rng.standard_normal((Bsz, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, (Bsz, S, H)).astype(np.float32)
    A = -rng.uniform(0.1, 1.0, (H,)).astype(np.float32)
    Bm = rng.standard_normal((Bsz, S, N)).astype(np.float32)
    Cm = rng.standard_normal((Bsz, S, N)).astype(np.float32)
    y, hT = L.ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
                          jnp.asarray(Bm), jnp.asarray(Cm), chunk=chunk)
    y0, h0 = _naive_ssm(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y0, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), h0, rtol=1e-3, atol=1e-3)


def test_mamba_decode_matches_train():
    """Stepwise decode through mamba_forward must match the chunked path."""
    cfg = get_config("mamba2-2.7b", smoke=True)
    key = jax.random.PRNGKey(1)
    p = L.make_mamba_params(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    y_train, _ = L.mamba_forward(p, x, cfg, state=None)
    state = L.init_mamba_state(cfg, 2)
    ys = []
    for t in range(8):
        y_t, state = L.mamba_forward(p, x[:, t:t + 1], cfg, state=state)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=3e-3, atol=3e-3)


# --------------------------------------------------------------------------
# MoE: sorted-capacity dispatch vs explicit dense reference
# --------------------------------------------------------------------------

def _moe_dense_ref(p, x, cfg):
    B, S, d = x.shape
    xt = np.asarray(x.reshape(B * S, d), np.float64)
    logits = xt @ np.asarray(p["w_gate_router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.top_k
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:k]
        w = probs[t][top]
        if cfg.renorm_topk:
            w = w / w.sum()
        for e, wi in zip(top, w):
            h = xt[t] @ np.asarray(p["w1"][e], np.float64)
            h = h / (1 + np.exp(-h)) * (xt[t] @ np.asarray(p["w2"][e],
                                                           np.float64))
            out[t] += wi * (h @ np.asarray(p["w3"][e], np.float64))
    return out.reshape(B, S, d)


def test_moe_matches_dense_reference():
    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              capacity_factor=8.0, n_shared=0)
    key = jax.random.PRNGKey(2)
    p = L.make_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 6, cfg.d_model), jnp.float32)
    y = L.moe_forward(p, x, cfg)
    y0 = _moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), y0, rtol=2e-3, atol=2e-3)


def test_moe_group_invariance():
    """Dispatch groups must not change the result (capacity permitting)."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(5)
    p = L.make_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    y1 = L.moe_forward(p, x, cfg)
    y2 = L.moe_forward(p, x, dataclasses.replace(cfg, moe_groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, True), (6, 0.0, True), (0, 30.0, True), (0, 0.0, False),
    (4, 20.0, True),
])
def test_flash_equals_full(window, softcap, causal):
    rng = np.random.default_rng(3)
    B, H, S, hd = 2, 3, 32, 8
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = L.attend_full(q, k, v, q_positions=pos, kv_positions=pos,
                         window=window, softcap=softcap, causal=causal)
    flash = L.attend_flash(q, k, v, q_positions=pos, kv_positions=pos,
                           window=window, softcap=softcap, causal=causal,
                           q_block=8, kv_block=8)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_flash_gradients():
    rng = np.random.default_rng(4)
    B, H, S, hd = 1, 2, 16, 4
    q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(
            q, k, v, q_positions=pos, kv_positions=pos, window=4)))

    g1 = jax.grad(loss(lambda *a, **kw: L.attend_flash(
        *a, q_block=4, kv_block=4, **kw)), argnums=(0, 1, 2))(q, k, v)
    g0 = jax.grad(loss(L.attend_full), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


if HAVE_HYPOTHESIS:
    @requires_hypothesis
    @settings(max_examples=15, deadline=None)
    @given(pos0=st.integers(0, 1000), theta=st.sampled_from([1e4, 1e6]))
    def test_rope_preserves_norm_and_relativity(pos0, theta):
        """RoPE is a rotation (norm-preserving) and relative: the score of
        (q at p+delta, k at p) is independent of p."""
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((1, 2, 1, 8)), jnp.float32)
        pos = jnp.asarray([[pos0, pos0 + 3]])
        y = L.rope(x, pos, theta)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-4)
        q = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((8,)), jnp.float32)

        def score(p):
            qr = L.rope(q[None, None, None], jnp.asarray([[p + 3]]), theta)
            kr = L.rope(k[None, None, None], jnp.asarray([[p]]), theta)
            return float(jnp.sum(qr * kr))

        assert abs(score(pos0) - score(0)) < 1e-2
else:
    @requires_hypothesis
    def test_rope_preserves_norm_and_relativity():
        pass
