"""Bench JSON schema tolerance + the CI perf-regression gate."""
import json

import pytest

from benchmarks import bench_schema, compare_baseline, update_baseline


# --------------------------------------------------------------------------
# Schema: floats and {us_per_call, config} dicts both normalize
# --------------------------------------------------------------------------

def test_normalize_accepts_float_and_dict_entries():
    data = {
        "plain": 123.4,
        "integral": 7,
        "tuned": {"us_per_call": 88.0,
                  "config": {"backend": "fft-xla", "bm": 16}},
        "bare_dict": {"us_per_call": 9},
    }
    norm = bench_schema.normalize(data)
    assert norm["plain"] == {"us_per_call": 123.4, "config": {}}
    assert norm["integral"]["us_per_call"] == 7.0
    assert norm["tuned"]["config"]["backend"] == "fft-xla"
    assert norm["bare_dict"] == {"us_per_call": 9.0, "config": {}}


def test_normalize_preserves_percentiles_field():
    data = {"serve/b4/p99": {
        "us_per_call": 910.0,
        "percentiles": {"p50": 618, "p99": 910.0},
        "config": {"mode": "bucketed", "replicas": 1}}}
    norm = bench_schema.normalize(data)
    entry = norm["serve/b4/p99"]
    assert entry["us_per_call"] == 910.0
    assert entry["percentiles"] == {"p50": 618.0, "p99": 910.0}
    assert entry["config"]["mode"] == "bucketed"
    # rows without percentiles stay percentile-free (no key injection)
    assert "percentiles" not in bench_schema.normalize(
        {"a": {"us_per_call": 1.0}})["a"]


@pytest.mark.parametrize("bad", [
    {"x": "fast"}, {"x": True}, {"x": [1, 2]},
    {"x": {"config": {}}},                       # missing us_per_call
    {"x": {"us_per_call": "slow"}},
    {"x": {"us_per_call": 1.0, "config": 3}},
    {"x": {"us_per_call": 1.0, "percentiles": [50, 99]}},
    {"x": {"us_per_call": 1.0, "percentiles": {"p50": "slow"}}},
    {"x": {"us_per_call": 1.0, "percentiles": {"p50": True}}},
    "not a dict",
])
def test_normalize_rejects_malformed(bad):
    with pytest.raises(ValueError):
        bench_schema.normalize(bad)


def test_run_py_csv_parser_still_float_only():
    from benchmarks.run import parse_csv_rows
    rows = parse_csv_rows("name,us_per_call\n# note\na,5.0,x\nb,oops\n")
    assert rows == {"a": 5.0}


# --------------------------------------------------------------------------
# The gate
# --------------------------------------------------------------------------

def _write(path, data):
    path.write_text(json.dumps(data))
    return str(path)


def test_gate_passes_within_tolerance(tmp_path, capsys):
    base = _write(tmp_path / "base.json", {"a": 100.0, "b": 50.0})
    cur = _write(tmp_path / "cur.json",
                 {"a": 200.0, "b": {"us_per_call": 40.0, "config": {}}})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--tolerance", "2.5"]) == 0
    out = capsys.readouterr().out
    assert "perf gate OK" in out and "2 compared" in out


def test_gate_fails_on_synthetic_regression(tmp_path, capsys):
    base = _write(tmp_path / "base.json", {"a": 100.0, "b": 50.0})
    cur = _write(tmp_path / "cur.json", {"a": 300.0, "b": 50.0})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--tolerance", "2.5"]) == 1
    captured = capsys.readouterr()
    assert "REGRESSED" in captured.out           # delta table row
    assert "3.00" in captured.out                # the ratio is printed
    assert "perf gate FAILED" in captured.err


def test_gate_tolerance_is_a_knob(tmp_path):
    base = _write(tmp_path / "base.json", {"a": 100.0})
    cur = _write(tmp_path / "cur.json", {"a": 300.0})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--tolerance", "4"]) == 0


def _serve_row(us):
    return {"us_per_call": us,
            "percentiles": {"p50": us / 2.0, "p99": us},
            "config": {"mode": "bucketed", "replicas": 1}}


def test_gate_catches_serve_p99_blowup(tmp_path, capsys):
    """The SLO gate: a synthetic 10x p99 blowup on a serve row must
    fail the baseline comparison (percentiles ride along untouched)."""
    base = _write(tmp_path / "base.json",
                  {"serve/b4/p99": _serve_row(1000.0),
                   "serve/b4/p50": _serve_row(600.0)})
    cur = _write(tmp_path / "cur.json",
                 {"serve/b4/p99": _serve_row(10000.0),
                  "serve/b4/p50": _serve_row(600.0)})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--tolerance", "2.5"]) == 1
    captured = capsys.readouterr()
    assert "serve/b4/p99" in captured.out and "REGRESSED" in captured.out
    # within tolerance the same rows pass
    ok = _write(tmp_path / "ok.json",
                {"serve/b4/p99": _serve_row(1200.0),
                 "serve/b4/p50": _serve_row(600.0)})
    assert compare_baseline.main(
        ["--baseline", base, "--current", ok, "--tolerance", "2.5"]) == 0


def test_update_baseline_round_trips_percentiles(tmp_path):
    src = _write(tmp_path / "cur.json", {"serve/b2/p99": _serve_row(80.0)})
    out = tmp_path / "BENCH_baseline.json"
    assert update_baseline.main(["--from", src, "--out", str(out)]) == 0
    data = json.loads(out.read_text())
    assert data["serve/b2/p99"]["percentiles"] == {"p50": 40.0,
                                                   "p99": 80.0}


def test_gate_min_us_floor_skips_jitter(tmp_path, capsys):
    base = _write(tmp_path / "base.json", {"tiny": 2.0, "big": 1000.0})
    cur = _write(tmp_path / "cur.json", {"tiny": 50.0, "big": 1000.0})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--min-us", "10"]) == 0
    assert "skipped" in capsys.readouterr().out


def test_gate_missing_and_new_entries(tmp_path, capsys):
    base = _write(tmp_path / "base.json", {"gone": 10.0, "kept": 10.0})
    cur = _write(tmp_path / "cur.json", {"kept": 10.0, "fresh": 10.0})
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur]) == 0   # tolerant by default
    out = capsys.readouterr().out
    assert "MISSING" in out and "NEW" in out
    assert compare_baseline.main(
        ["--baseline", base, "--current", cur, "--strict-missing"]) == 1


def test_gate_rejects_empty_or_malformed_current(tmp_path):
    base = _write(tmp_path / "base.json", {"a": 1.0})
    empty = _write(tmp_path / "empty.json", {})
    assert compare_baseline.main(
        ["--baseline", base, "--current", empty]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{broken")
    assert compare_baseline.main(
        ["--baseline", base, "--current", str(bad)]) == 2


def test_committed_baseline_is_schema_valid():
    import os
    path = os.path.join(os.path.dirname(compare_baseline.__file__),
                        "BENCH_baseline.json")
    data = bench_schema.load_normalized(path)
    assert len(data) >= 10
    assert all(v["us_per_call"] > 0 for v in data.values())


def test_update_baseline_from_existing(tmp_path, capsys):
    src = _write(tmp_path / "cur.json",
                 {"a": 5.0, "t": {"us_per_call": 7.0,
                                  "config": {"backend": "direct"}}})
    out = tmp_path / "BENCH_baseline.json"
    assert update_baseline.main(["--from", src, "--out", str(out)]) == 0
    data = json.loads(out.read_text())
    assert data["a"] == {"us_per_call": 5.0, "config": {}}
    assert data["t"]["config"] == {"backend": "direct"}


def test_update_baseline_refuses_empty(tmp_path):
    src = _write(tmp_path / "cur.json", {})
    with pytest.raises(SystemExit):
        update_baseline.main(["--from", src,
                              "--out", str(tmp_path / "o.json")])
