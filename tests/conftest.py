import os
import sys

# NOTE: per the dry-run contract, tests run on the REAL single CPU device —
# XLA_FLAGS device-count forcing happens only in subprocess-based tests and
# in repro.launch.dryrun itself.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
