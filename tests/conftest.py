import os
import sys

import pytest

# NOTE: per the dry-run contract, tests run on the REAL single CPU device —
# XLA_FLAGS device-count forcing happens only in subprocess-based tests and
# in repro.launch.dryrun itself.
_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)              # the `benchmarks` namespace package

# hypothesis is an optional test extra: property tests skip without it.
try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")
