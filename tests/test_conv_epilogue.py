"""Fused-epilogue acceptance: parity vs the unfused+elementwise oracle on
every backend x schedule pair, ZERO extra collectives / stage ops (jaxpr +
stage-count asserted on nfft and wfft), gradients for (x, k, bias) through
a fused plan, prepared-plan epilogue amortization, and the thread-safe
``stage_trace`` context manager."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import Epilogue, analyze, plan_conv, stage_trace
from repro.conv.epilogue import ACTIVATIONS, apply_epilogue
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


PAIRS = [("direct", "local", None), ("fft-xla", "local", None),
         ("fft-pallas", "local", None),
         ("fft-xla", "nfft", _mesh11), ("fft-xla", "wfft", _mesh11),
         ("fft-pallas", "nfft", _mesh11), ("fft-pallas", "wfft", _mesh11)]

EPILOGUES = [Epilogue(bias=True, activation="relu"),
             Epilogue(bias=True, activation="silu", residual=True),
             Epilogue(activation="gelu")]


def _operands(plan, ep, seed):
    bias = _rand((plan.spec.Cout,), seed) if ep.bias else None
    residual = _rand(plan.out_shape, seed + 1) if ep.residual else None
    return bias, residual


@pytest.mark.parametrize("backend,schedule,mesh_fn", PAIRS)
@pytest.mark.parametrize("ep", EPILOGUES, ids=lambda e: e.describe())
def test_fused_matches_unfused_oracle(backend, schedule, mesh_fn, ep):
    """fused plan == unfused plan + explicit bias/act/residual, and both
    match the direct-conv oracle + the same elementwise tail."""
    mesh = mesh_fn() if mesh_fn else None
    x, k = _rand((2, 3, 18, 18), 1), _rand((4, 3, 3, 3), 2)
    fused = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                      schedule=schedule, mesh=mesh, epilogue=ep)
    unfused = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                        schedule=schedule, mesh=mesh)
    assert fused is not unfused          # epilogue is part of the cache key
    bias, residual = _operands(fused, ep, 3)
    y = fused(x, k, bias=bias, residual=residual)
    y0 = apply_epilogue(unfused(x, k), ep, bias=bias, residual=residual)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)
    oracle = apply_epilogue(conv2d_direct(x, k, padding=1), ep,
                            bias=bias, residual=residual)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("schedule", ["nfft", "wfft"])
def test_fusion_adds_zero_collectives_and_zero_stage_ops(schedule):
    """THE acceptance criterion: the fused epilogue rides the existing
    stage-4 op and the traced program has exactly the same collective
    equations as the unfused plan.  The static analyzer traces the fused
    plan AND its epilogue-stripped twin and reports the delta."""
    mesh = _mesh11()
    ep = Epilogue(bias=True, activation="relu", residual=True)
    x, k = _rand((2, 4, 20, 20), 4), _rand((4, 4, 3, 3), 5)
    fused = plan_conv(x.shape, k.shape, padding=1, schedule=schedule,
                      mesh=mesh, epilogue=ep)
    profile = analyze(fused)

    assert profile.epilogue_delta is not None
    assert all(v == 0 for v in profile.epilogue_delta["collectives"].values())
    assert all(v == 0
               for v in profile.epilogue_delta["stage_counts"].values())
    if schedule == "wfft":
        assert profile.collectives["psum"] == 2    # the hot-stage psum pair
        assert profile.collectives["all_to_all"] == 0
    else:
        assert profile.collectives["all_to_all"] == 6
        assert profile.collectives["psum"] == 0
    profile.check().raise_if_failed()


@pytest.mark.parametrize("backend,schedule,mesh_fn", [
    ("direct", "local", None), ("fft-xla", "local", None),
    ("fft-pallas", "local", None), ("fft-xla", "nfft", _mesh11),
    ("fft-xla", "wfft", _mesh11)])
def test_grad_x_k_bias_through_fused_plan(backend, schedule, mesh_fn):
    """d(x, k, bias) through a fused bias+act plan vs the direct oracle
    with the same explicit elementwise tail."""
    mesh = mesh_fn() if mesh_fn else None
    ep = Epilogue(bias=True, activation="relu")
    x, k = _rand((2, 3, 14, 14), 7), _rand((4, 3, 3, 3), 8)
    bias = _rand((4,), 9)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                     schedule=schedule, mesh=mesh, epilogue=ep)

    def loss_fused(x, k, b):
        return jnp.sum(jnp.sin(plan(x, k, bias=b)))

    def loss_oracle(x, k, b):
        y = jax.nn.relu(conv2d_direct(x, k, padding=1)
                        + b[None, :, None, None])
        return jnp.sum(jnp.sin(y))

    g = jax.grad(loss_fused, argnums=(0, 1, 2))(x, k, bias)
    g0 = jax.grad(loss_oracle, argnums=(0, 1, 2))(x, k, bias)
    for a, b, name in zip(g, g0, ("dx", "dk", "dbias")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3, err_msg=name)


def test_grad_residual_through_fused_plan():
    ep = Epilogue(bias=True, activation="silu", residual=True)
    x, k = _rand((1, 2, 12, 12), 10), _rand((2, 2, 3, 3), 11)
    bias, res = _rand((2,), 12), _rand((1, 2, 12, 12), 13)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla",
                     epilogue=ep)
    g = jax.grad(lambda r: jnp.sum(jnp.sin(
        plan(x, k, bias=bias, residual=r))))(res)
    g0 = jax.grad(lambda r: jnp.sum(jnp.sin(jax.nn.silu(
        conv2d_direct(x, k, padding=1) + bias[None, :, None, None]
        + r))))(res)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("backend,schedule,mesh_fn", [
    ("fft-xla", "local", None), ("fft-pallas", "local", None),
    ("fft-xla", "nfft", _mesh11), ("fft-xla", "wfft", _mesh11)])
def test_prepared_epilogue_parity_and_stage_counts(backend, schedule,
                                                   mesh_fn):
    """Prepared + fused epilogue: numerics match one-shot fused execution
    AND the prepared stage counts are unchanged vs an unfused prepared
    plan (the epilogue amortizes with the kernel transform, costing no
    extra stage work per call)."""
    mesh = mesh_fn() if mesh_fn else None
    ep = Epilogue(bias=True, activation="relu")
    x, k = _rand((2, 3, 16, 16), 14), _rand((4, 3, 3, 3), 15)
    fused = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                      schedule=schedule, mesh=mesh, epilogue=ep)
    unfused = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                        schedule=schedule, mesh=mesh)
    bias = _rand((4,), 16)

    pf, pu = fused.prepare(k), unfused.prepare(k)
    np.testing.assert_allclose(np.asarray(pf(x, bias=bias)),
                               np.asarray(fused(x, k, bias=bias)),
                               rtol=2e-5, atol=2e-5)
    with stage_trace() as cf:
        jax.make_jaxpr(lambda a, b: pf(a, bias=b))(x, bias)
    with stage_trace() as cu:
        jax.make_jaxpr(pu)(x)
    assert dict(cf) == dict(cu)


def test_epilogue_operand_validation():
    ep = Epilogue(bias=True, activation="relu")
    x, k = _rand((1, 2, 12, 12), 17), _rand((2, 2, 3, 3), 18)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla",
                     epilogue=ep)
    with pytest.raises(ValueError, match="declares bias=True"):
        plan(x, k)
    with pytest.raises(ValueError, match="bias must have shape"):
        plan(x, k, bias=_rand((3,), 19))
    plain = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")
    with pytest.raises(ValueError, match="declares bias=False"):
        plain(x, k, bias=_rand((2,), 20))
    with pytest.raises(ValueError, match="unknown epilogue activation"):
        Epilogue(activation="tanh")


def test_epilogue_fuses_before_output_cast():
    """The epilogue runs in f32 BEFORE the x.dtype cast: a bf16 input
    still gets an f32-accurate elementwise tail."""
    ep = Epilogue(bias=True, activation="gelu")
    x = _rand((1, 2, 12, 12), 21).astype(jnp.bfloat16)
    k, bias = _rand((2, 2, 3, 3), 22), _rand((2,), 23)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla",
                     epilogue=ep)
    y = plan(x, k, bias=bias)
    assert y.dtype == jnp.bfloat16
    y0 = ACTIVATIONS["gelu"](
        conv2d_direct(x.astype(jnp.float32), k, padding=1)
        + bias[None, :, None, None]).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=3e-2, atol=3e-2)


# --------------------------------------------------------------------------
# stage_trace: thread-safe, context-managed counters (satellite)
# --------------------------------------------------------------------------

def _run_stage_op(seed):
    """One eager stage-op invocation (increments the counters exactly once
    per call — unlike re-tracing a plan, which jax memoizes per
    (plan, avals) so repeat traces never re-enter Python)."""
    from repro.conv import stages
    from repro.core.conv_spec import ConvSpec
    spec = ConvSpec(B=1, C=1, Cout=1, H=8, W=8, kh=3, kw=3,
                    pad_h=1, pad_w=1, delta=16)
    stages.stage_input_transform(_rand((1, 1, 8, 8), seed), spec)


def test_stage_trace_nested():
    """Nested traces each count their own window; the outer sees both.
    The old global-counter shims (``stage_counts``/``reset_stage_counts``)
    are gone — ``stage_trace`` is the only counting surface."""
    import repro.conv as conv_pkg
    assert not hasattr(conv_pkg, "stage_counts")
    assert not hasattr(conv_pkg, "reset_stage_counts")
    with stage_trace() as outer:
        _run_stage_op(24)
        with stage_trace() as inner:
            _run_stage_op(25)
    assert inner["input_transform"] == 1
    assert outer["input_transform"] == 2       # outer sees nested trace too


def test_stage_trace_empty_nested_traces_unwind_cleanly():
    """Regression: teardown must remove the counter by IDENTITY — two
    still-empty nested Counters compare equal, and equality-based removal
    popped the wrong one (miscounts, then ValueError on outer exit)."""
    with stage_trace() as outer:
        with stage_trace():
            pass
        _run_stage_op(28)                       # credited to outer only
    assert outer["input_transform"] == 1


def test_stage_trace_is_thread_isolated():
    """Concurrent tracers each observe only their own thread's stage ops
    (the module-global Counter behind the shim would bleed)."""
    results, errors = {}, []

    def worker(name, n):
        try:
            with stage_trace() as c:
                for i in range(n):
                    _run_stage_op(100 + n + i)
            results[name] = dict(c)
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=("a", 2)),
               threading.Thread(target=worker, args=("b", 3))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert results["a"]["input_transform"] == 2
    assert results["b"]["input_transform"] == 3
