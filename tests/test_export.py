"""AOT-exported plan artifacts (``repro.conv.export``): round-trip
parity against live-planned execution, compatibility-mismatch fallback,
fingerprint certification, the spec-first kwarg unification, the
``keystr`` checkpoint key fix, and plan artifacts riding checkpoints."""
import json
import os
import subprocess
import sys
import warnings
import zipfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import (
    Epilogue, NetworkConv, export_network, load_network, plan_conv,
    plan_network,
)
from repro.conv import export as planx

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _rand(shape, seed=0, s=0.5):
    return jnp.asarray(
        s * np.random.default_rng(seed).standard_normal(shape),
        jnp.float32)


def _net(schedule="auto", mesh=None, spectrum="auto", batch=2, image=8):
    layers = [
        NetworkConv("c1", (batch, 2, image, image), (4, 2, 3, 3),
                    padding=1, epilogue=Epilogue(bias=True,
                                                 activation="relu")),
        NetworkConv("c2", (batch, 4, image, image), (4, 4, 3, 3),
                    padding=1),
    ]
    return plan_network(layers, backend="fft-xla", schedule=schedule,
                        mesh=mesh, spectrum=spectrum)


def _params():
    return {"c1": _rand((4, 2, 3, 3), 1), "c2": _rand((4, 4, 3, 3), 2)}


def _run_live(net, prepared_net, x, bias):
    y = prepared_net["c1"](x, bias=bias)
    return prepared_net["c2"](y)


# --------------------------------------------------------------------------
# Round-trip parity: {local, nfft} x {real spectrum} x {prepared, raw}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("schedule,spectrum", [
    ("local", "auto"), ("local", "real"), ("nfft", "auto"),
])
@pytest.mark.parametrize("prepared", [True, False])
def test_roundtrip_parity(tmp_path, schedule, spectrum, prepared):
    mesh = make_mesh((1, 1), ("data", "model")) \
        if schedule == "nfft" else None
    net = _net(schedule=schedule, mesh=mesh, spectrum=spectrum)
    params = _params()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=params if prepared else None,
               weights_version=3)

    prep = net.prepare(params, weights_version=3)
    x = _rand((2, 2, 8, 8), 7, s=1.0)
    bias = _rand((4,), 9)
    want = _run_live(net, prep, x, bias)

    loaded = load_network(path)
    assert loaded.source == "aot"
    assert loaded.weights_version == 3
    if prepared:
        got = loaded["c2"](loaded["c1"](x, bias=bias))
    else:
        got = loaded["c2"](loaded["c1"](x, params["c1"], bias=bias),
                           params["c2"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_loaded_layer_arg_conventions(tmp_path):
    net = _net()
    params = _params()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=params)
    loaded = load_network(path)
    x = _rand((2, 2, 8, 8), 3)
    with pytest.raises(TypeError, match="takes only x"):
        loaded["c1"](x, params["c1"], bias=_rand((4,), 1))
    with pytest.raises(ValueError, match="bias"):
        loaded["c1"](x)                     # epilogue declares bias
    with pytest.raises(ValueError, match="bias"):
        loaded["c2"](x, bias=_rand((4,), 1))   # c2 has no bias


# --------------------------------------------------------------------------
# Native-executable fast path and its StableHLO fallback
# --------------------------------------------------------------------------

def test_native_exe_and_stablehlo_agree(tmp_path):
    net = _net()
    params = _params()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=params)
    man = planx.read_manifest(path)
    entries = man["nets"]["net"]["layers"]
    assert all(e.get("exe") for e in entries.values()), \
        "export should ship native executables on this backend"

    x = _rand((2, 2, 8, 8), 5, s=1.0)
    bias = _rand((4,), 6)
    native = load_network(path)
    assert all(lc.native for lc in native.layers.values())
    y_native = native["c2"](native["c1"](x, bias=bias))

    # sabotage the exe blobs -> per-layer fallback to the portable module
    broken = str(tmp_path / "noexe.rpa")
    with zipfile.ZipFile(path) as zin, \
            zipfile.ZipFile(broken, "w") as zout:
        for m in zin.namelist():
            data = zin.read(m)
            if m.startswith("exe/"):
                data = b"not a pickle"
            zout.writestr(m, data)
    portable = load_network(broken)
    assert portable.source == "aot"
    assert not any(lc.native for lc in portable.layers.values())
    y_port = portable["c2"](portable["c1"](x, bias=bias))
    np.testing.assert_allclose(np.asarray(y_native), np.asarray(y_port),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Fresh-process rehydration (the actual fleet cold-start path)
# --------------------------------------------------------------------------

_SUBPROC = r"""
import json, sys
import jax.numpy as jnp
import numpy as np
from repro.conv import load_network
loaded = load_network(sys.argv[1])
assert loaded.source == "aot", loaded.source
rng = np.random.default_rng(7)
x = jnp.asarray(0.5 * rng.standard_normal((2, 2, 8, 8)), jnp.float32)
rng9 = np.random.default_rng(9)
bias = jnp.asarray(0.5 * rng9.standard_normal((4,)), jnp.float32)
y = loaded["c2"](loaded["c1"](x, bias=bias))
print("RESULT" + json.dumps(np.asarray(y).ravel().tolist()))
"""


def test_subprocess_bitwise_parity(tmp_path):
    net = _net()
    params = _params()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=params)

    prep = net.prepare(params, weights_version=None)
    rng = np.random.default_rng(7)
    x = jnp.asarray(0.5 * rng.standard_normal((2, 2, 8, 8)), jnp.float32)
    rng9 = np.random.default_rng(9)
    bias = jnp.asarray(0.5 * rng9.standard_normal((4,)), jnp.float32)
    want = np.asarray(_run_live(net, prep, x, bias))

    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC, path],
                       env=env, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT")][0]
    got = np.asarray(json.loads(line[len("RESULT"):]),
                     np.float32).reshape(want.shape)
    # same device kind, same jax, same module: bitwise
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# Compatibility mismatch -> live fallback (or error)
# --------------------------------------------------------------------------

def _tamper(path, out, **fields):
    with zipfile.ZipFile(path) as zin, zipfile.ZipFile(out, "w") as zout:
        for m in zin.namelist():
            data = zin.read(m)
            if m == "manifest.json":
                man = json.loads(data)
                man.update(fields)
                data = json.dumps(man)
            zout.writestr(m, data)
    return out


@pytest.mark.parametrize("fields", [
    {"jax_version": "0.0.1"},
    {"device_kind": "TPU v9000"},
])
def test_mismatch_falls_back_to_live(tmp_path, fields):
    net = _net()
    params = _params()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=params, weights_version=1)
    bad = _tamper(path, str(tmp_path / "bad.rpa"), **fields)

    with pytest.warns(UserWarning, match="falling back to live planning"):
        loaded = load_network(bad)
    assert loaded.source == "live"

    x = _rand((2, 2, 8, 8), 7, s=1.0)
    bias = _rand((4,), 9)
    prep = net.prepare(params, weights_version=1)
    want = _run_live(net, prep, x, bias)
    got = loaded["c2"](loaded["c1"](x, bias=bias))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    with pytest.raises(planx.ArtifactMismatch):
        load_network(bad, on_mismatch="error")
    with pytest.raises(ValueError, match="on_mismatch"):
        load_network(bad, on_mismatch="explode")


def test_verify_fingerprints(tmp_path):
    net = _net()
    path = str(tmp_path / "net.rpa")
    net.export(path, params=_params())
    v = planx.verify(path)
    assert v["ok"] and v["n_checked"] == 2 and not v["mismatches"]

    # corrupt one stamp -> verify names the layer
    with zipfile.ZipFile(path) as zf:
        man = json.loads(zf.read("manifest.json"))
    man["nets"]["net"]["layers"]["c1"]["fingerprint"] = "sha256:bogus"
    bad = str(tmp_path / "bad.rpa")
    with zipfile.ZipFile(path) as zin, zipfile.ZipFile(bad, "w") as zout:
        for m in zin.namelist():
            zout.writestr(m, json.dumps(man) if m == "manifest.json"
                          else zin.read(m))
    v = planx.verify(bad)
    assert not v["ok"]
    assert [m["layer"] for m in v["mismatches"]] == ["c1"]


def test_bucketed_export_labels(tmp_path):
    def make_layers(b):
        return [NetworkConv("c1", (b, 2, 8, 8), (4, 2, 3, 3), padding=1)]
    nets = plan_network(make_layers, buckets=(1, 2), backend="fft-xla")
    path = str(tmp_path / "b.rpa")
    nets.export(path, params={"c1": _rand((4, 2, 3, 3), 1)})
    loaded = load_network(path)
    assert sorted(loaded) == ["b1", "b2"]
    assert loaded["b2"]["c1"].x_shape == (2, 2, 8, 8)


# --------------------------------------------------------------------------
# Spec-first kwarg unification (plan_conv / tune take a ConvSpec)
# --------------------------------------------------------------------------

def test_plan_conv_spec_first():
    from repro.core.conv_spec import ConvSpec
    spec = ConvSpec(B=2, C=2, Cout=4, H=8, W=8, kh=3, kw=3,
                    pad_h=1, pad_w=1)
    a = plan_conv(spec, backend="fft-xla")
    b = plan_conv((2, 2, 8, 8), (4, 2, 3, 3), padding=1,
                  backend="fft-xla")
    assert a is b                       # identical cache entry
    with pytest.raises(TypeError, match="already carries"):
        plan_conv(spec, (4, 2, 3, 3))
    with pytest.raises(TypeError, match="k_shape"):
        plan_conv((2, 2, 8, 8))


def test_tune_spec_first(tmp_path, monkeypatch):
    from repro.conv import autotune
    from repro.core.conv_spec import ConvSpec
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_REPS", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_BUDGET_MS", "200")
    spec = ConvSpec(B=1, C=2, Cout=2, H=8, W=8, kh=3, kw=3)
    cfg = autotune.tune(spec, reps=1)
    cfg2 = autotune.tune((1, 2, 8, 8), (2, 2, 3, 3), padding=(0, 0),
                         reps=1)
    assert cfg.backend == cfg2.backend
    assert cfg.schedule == cfg2.schedule
    with pytest.raises(TypeError, match="already carries"):
        autotune.tune(spec, (2, 2, 3, 3))


# --------------------------------------------------------------------------
# Checkpoint keys: keystr fix + legacy restore + plan artifacts
# --------------------------------------------------------------------------

def test_checkpoint_keystr_roundtrip(tmp_path):
    import collections
    from repro import checkpoint
    Pair = collections.namedtuple("Pair", ["w", "b"])
    tree = {
        "a": {"b": jnp.arange(3.0)},
        "a.b": jnp.arange(4.0),            # collides under the old join
        "lst": [jnp.ones((2,)), Pair(w=jnp.zeros((2, 2)),
                                     b=jnp.full((1,), 7.0))],
    }
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, tree, weights_version=5)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got, meta = checkpoint.restore(d, 1, like)
    assert meta["weights_version"] == 5
    assert meta["format"] == 2
    flat_a, _ = jax.tree_util.tree_flatten(tree)
    flat_b, _ = jax.tree_util.tree_flatten(got)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_legacy_layout_restores(tmp_path):
    from repro import checkpoint
    tree = {"w": jnp.arange(4.0), "inner": {"b": jnp.ones((2,))}}
    d = str(tmp_path / "ck" / "step_00000003")
    os.makedirs(d)
    # hand-write the pre-keystr layout: <joined-key>.npy, no files map
    np.save(os.path.join(d, "w.npy"), np.arange(4.0, dtype=np.float32))
    np.save(os.path.join(d, "inner.b.npy"), np.ones((2,), np.float32))
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"step": 3, "keys": ["inner.b", "w"], "extra": {}}, f)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got, meta = checkpoint.restore(str(tmp_path / "ck"), 3, like)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(4.0, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(got["inner"]["b"]),
                                  np.ones((2,), np.float32))


def test_plan_artifact_rides_checkpoint(tmp_path):
    from repro import checkpoint
    net = _net()
    params = _params()
    d = str(tmp_path / "ck")
    with pytest.raises(FileNotFoundError, match="save the weights"):
        checkpoint.save_plan_artifact(d, 2, net, params)
    checkpoint.save(d, 2, params, weights_version=2)
    assert not checkpoint.has_plan_artifact(d, 2)
    checkpoint.save_plan_artifact(d, 2, net, params)
    assert checkpoint.has_plan_artifact(d, 2)
    loaded = checkpoint.load_plan_artifact(d, 2)
    assert loaded.source == "aot"
    assert loaded.weights_version == 2      # defaults to the step
    with pytest.raises(FileNotFoundError, match="no plan artifact"):
        checkpoint.load_plan_artifact(d, 99)


# --------------------------------------------------------------------------
# ServeEngine: export_plans / load_plans
# --------------------------------------------------------------------------

def _engine_bits():
    def make_layers(b):
        return [
            NetworkConv("s1", (b, 2, 8, 8), (4, 2, 3, 3), padding=1),
            NetworkConv("s2", (b, 4, 8, 8), (4, 4, 3, 3), padding=1),
        ]

    params = {"s1": _rand((4, 2, 3, 3), 1), "s2": _rand((4, 4, 3, 3), 2)}
    return make_layers, params


def test_engine_export_load_parity_zero_misses(tmp_path):
    from repro.conv.plan import plan_cache_info
    from repro.launch.batcher import BucketPolicy, ServeEngine
    make_layers, params = _engine_bits()
    policy = BucketPolicy(max_batch=2)
    live = ServeEngine(make_layers, params, policy=policy,
                       backend="fft-xla", collect_results=True)
    path = str(tmp_path / "plans.rpa")
    live.export_plans(path)

    aot = ServeEngine(make_layers, params, policy=policy,
                      backend="fft-xla", collect_results=True,
                      load_plans=path)
    assert aot.plan_source == "aot"
    with pytest.raises(RuntimeError, match="export_plans"):
        aot.export_plans(str(tmp_path / "again.rpa"))

    x = _rand((2, 2, 8, 8), 11, s=1.0)
    misses0 = plan_cache_info().misses
    ra = aot.submit(x)
    rl = live.submit(x)
    aot.drain()
    live.drain()
    assert plan_cache_info().misses == misses0   # nothing planned
    np.testing.assert_allclose(np.asarray(aot.results[ra]),
                               np.asarray(live.results[rl]),
                               rtol=1e-5, atol=1e-5)
    assert aot.report()["plan_cache_misses_after_warmup"] == 0

    # weight update drops the artifact and re-plans live
    params2 = {k: v + 0.01 for k, v in params.items()}
    aot.update_weights(params2, weights_version=1)
    assert aot.plan_source == "live"
    r2 = aot.submit(x)
    aot.drain()
    assert np.isfinite(np.asarray(aot.results[r2])).all()


def test_engine_stale_artifact_falls_back(tmp_path):
    from repro.launch.batcher import BucketPolicy, ServeEngine
    make_layers, params = _engine_bits()
    policy = BucketPolicy(max_batch=2)
    live = ServeEngine(make_layers, params, policy=policy,
                      backend="fft-xla")
    path = str(tmp_path / "plans.rpa")
    live.export_plans(path)

    with pytest.warns(UserWarning, match="falling back to live"):
        eng = ServeEngine(make_layers, params, policy=policy,
                          backend="fft-xla", load_plans=path,
                          weights_version=99)     # artifact holds None
    assert eng.plan_source == "live"
    rep = eng.report()
    assert rep["plan_source"] == "live"
    assert rep["startup_s"] > 0
