"""Expert-parallel MoE (boundary-a2a = the nFFT schedule) vs the TP-MoE
reference — subprocess with an 8-device host platform."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, re
import jax, jax.numpy as jnp
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
from repro.configs import get_config
from repro.models import layers as L
from repro.parallel.ep_moe import moe_forward_ep
cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                          capacity_factor=8.0, n_shared=0)
key = jax.random.PRNGKey(0)
p = L.make_moe_params(key, cfg)
x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
y_ref = L.moe_forward(p, x, cfg)
f = jax.jit(lambda p_, x_: moe_forward_ep(p_, x_, cfg, mesh))
y_ep = f(p, x)
err = float(jnp.max(jnp.abs(y_ep - y_ref))) / \
    float(jnp.max(jnp.abs(y_ref)))
assert err < 1e-4, err
hlo = f.lower(p, x).compile().as_text()
kinds = set(re.findall(r"(all-to-all|all-reduce)", hlo))
assert "all-to-all" in kinds and "all-reduce" not in kinds, kinds
print("EP_MOE_OK")
"""


@pytest.mark.slow
def test_ep_moe_matches_tp_and_keeps_hot_stage_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "EP_MOE_OK" in r.stdout
