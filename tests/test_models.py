"""Per-architecture smoke tests (reduced configs, CPU): one forward /
train step, shape + NaN assertions; prefill+decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import lm as LM
from repro.models import whisper as WH
from repro.models import layers as L
from repro.optim import AdamWConfig
from repro.train import make_train_step, init_train_state

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.encdec:
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, 24, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, 8)),
                                  jnp.int32),
        }
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                 jnp.int32)}
    if cfg.frontend == "vision_stub":
        out["img_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    batch = _batch(cfg)
    if cfg.encdec:
        p = WH.init_whisper_params(cfg, KEY)
        enc = WH.encode(p, cfg, batch["frames"])
        logits = WH.decode_train(p, cfg, enc, batch["tokens"])
        assert logits.shape == (B, 8, cfg.vocab)
    else:
        p = LM.init_lm_params(cfg, KEY)
        logits = LM.lm_forward(p, cfg, batch["tokens"],
                               img_embeds=batch.get("img_embeds"),
                               remat=False)
        extra = cfg.n_meta_tokens + (cfg.n_frontend_tokens
                                     if "img_embeds" in batch else 0)
        assert logits.shape == (B, S + extra, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params, opt = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3,
                                                    total_steps=10)))
    params, opt, m = step(params, opt, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert not any(bool(jnp.any(jnp.isnan(x)))
                   for x in jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ["qwen3-14b", "gemma2-27b", "hymba-1.5b",
                                  "mamba2-2.7b", "deepseek-v2-lite-16b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(x[:p]) then decode steps must reproduce teacher-forced
    forward logits (cache correctness)."""
    cfg = get_config(arch, smoke=True)
    p = LM.init_lm_params(cfg, KEY)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab, (B, 12)), jnp.int32)
    full = LM.lm_forward(p, cfg, toks, remat=False)     # (B, S+meta, V)
    meta = cfg.n_meta_tokens
    cache = LM.init_cache(cfg, B, 12 + meta + 4)
    lg, cache, _ = LM.lm_prefill(p, cfg, toks[:, :8], cache, use_flash=False)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, meta + 7]),
                               rtol=2e-2, atol=2e-2)
    pos = 8 + meta
    for i in range(2):
        lg, cache = LM.lm_decode_step(p, cfg, toks[:, 8 + i:9 + i],
                                      jnp.int32(pos + i), cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, meta + 8 + i]),
                                   rtol=2e-2, atol=2e-2)


def test_head_padding_exact():
    """Padded-head model must equal the unpadded model with embedded
    real weights (dead slots masked)."""
    cfg_pad = get_config("qwen3-14b", smoke=True)       # pad_heads=6
    cfg_ref = dataclasses.replace(cfg_pad, pad_heads=0)
    pp = LM.init_lm_params(cfg_pad, jax.random.PRNGKey(3))
    mask = np.asarray(L.head_mask(cfg_pad)).astype(bool)

    def fix(d):
        if isinstance(d, dict):
            out = {}
            for k, v in d.items():
                if k == "wq":
                    out[k] = v[..., mask, :]
                elif k == "wo":
                    out[k] = v[:, mask] if v.ndim == 4 else v[mask]
                else:
                    out[k] = fix(v)
            return out
        if isinstance(d, list):
            return [fix(x) for x in d]
        return d

    pr = fix(pp)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                              cfg_pad.vocab)
    y_pad = LM.lm_forward(pp, cfg_pad, toks, remat=False)
    y_ref = LM.lm_forward(pr, cfg_ref, toks, remat=False)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref),
                               atol=1e-3)


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper-small", smoke=True)
    p = WH.init_whisper_params(cfg, KEY)
    rng = np.random.default_rng(2)
    frames = jnp.asarray(rng.standard_normal((B, 24, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 6)), jnp.int32)
    enc = WH.encode(p, cfg, frames)
    full = WH.decode_train(p, cfg, enc, toks)
    cache = WH.prefill_cross(p, cfg, enc, WH.init_dec_cache(cfg, B, 24))
    for i in range(4):
        lg, cache = WH.decode_step(p, cfg, toks[:, i:i + 1], jnp.int32(i),
                                   cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-2, atol=2e-2)
