"""Continuous-batching serve engine: bucket policy edge cases, window
flush, pad-to-bucket parity, replica fairness, and plan/prepared-cache
dedupe across engines (the serving lifecycle from the ROADMAP)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.conv import (
    BucketedNetworkPlan, NetworkConv, clear_plan_cache,
    clear_prepared_cache, plan_cache_info, plan_network,
    prepared_cache_info,
)
from repro.launch.batcher import (
    BucketPolicy, RequestTooLarge, ServeEngine, TraceRequest, _percentile,
    run_trace, synthetic_trace,
)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _layers(batch, image=8):
    return [
        NetworkConv("s1", (batch, 2, image, image), (4, 2, 3, 3),
                    padding=1),
        NetworkConv("s2", (batch, 4, image, image), (4, 4, 3, 3),
                    padding=1),
    ]


def _params():
    return {"s1": _rand((4, 2, 3, 3), 1), "s2": _rand((4, 4, 3, 3), 2)}


def _engine(**kw):
    kw.setdefault("policy", BucketPolicy(max_batch=4))
    kw.setdefault("backend", "fft-xla")
    return ServeEngine(_layers, _params(), **kw)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# Bucket policy
# --------------------------------------------------------------------------

def test_batch_buckets_powers_of_two_max_included():
    assert BucketPolicy(max_batch=8).batch_buckets() == (1, 2, 4, 8)
    # non-power max is still its own bucket
    assert BucketPolicy(max_batch=6).batch_buckets() == (1, 2, 4, 6)
    assert BucketPolicy(max_batch=1).batch_buckets() == (1,)
    assert BucketPolicy(max_batch=8, min_batch=2).batch_buckets() == \
        (2, 4, 8)


def test_bucket_for_rounds_up():
    p = BucketPolicy(max_batch=8)
    assert [p.bucket_for(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]


def test_bucket_for_rejects_oversize_with_clear_error():
    p = BucketPolicy(max_batch=4)
    with pytest.raises(RequestTooLarge, match="max_batch=4"):
        p.bucket_for(5)
    with pytest.raises(ValueError, match=">= 1"):
        p.bucket_for(0)


def test_bucket_policy_validates_bounds_and_image_sizes():
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=2, min_batch=4)
    p = BucketPolicy(max_batch=4, image_sizes=(8, 16))
    assert p.bucket_for(2, image=8) == 2
    with pytest.raises(RequestTooLarge, match="image size"):
        p.bucket_for(2, image=32)


def test_percentile_nearest_rank():
    vals = [float(i) for i in range(1, 101)]
    assert _percentile(vals, 50) == pytest.approx(50.0, abs=1.0)
    assert _percentile(vals, 99) == pytest.approx(99.0, abs=1.0)
    assert _percentile([7.0], 99) == 7.0
    assert np.isnan(_percentile([], 50))


# --------------------------------------------------------------------------
# Engine edge cases
# --------------------------------------------------------------------------

def test_submit_oversize_rejected_and_counted():
    eng = _engine()
    with pytest.raises(RequestTooLarge):
        eng.submit(jnp.zeros((5, 2, 8, 8), jnp.float32))
    rep = eng.report()
    assert rep["n_rejected"] == 1 and rep["n_requests"] == 0


def test_drain_empty_queue_is_noop():
    eng = _engine()
    assert eng.drain() == 0
    assert eng.drain(force=True) == 0
    assert eng.queue_depth == 0


def test_window_holds_partial_batch_until_timeout():
    clock = FakeClock()
    eng = _engine(window_s=1.0, clock=clock)
    eng.submit(_rand((1, 2, 8, 8)))
    assert eng.drain() == 0 and eng.queue_depth == 1   # window open
    clock.t = 0.5
    assert eng.drain() == 0                            # still open
    clock.t = 1.5
    assert eng.drain() == 1 and eng.queue_depth == 0   # timed out: flush


def test_full_bucket_launches_inside_window():
    clock = FakeClock()
    eng = _engine(window_s=60.0, clock=clock)
    for i in range(4):
        eng.submit(_rand((1, 2, 8, 8), seed=i))
    assert eng.drain() == 1                 # max_batch rows: no waiting
    assert eng.report()["buckets"]["b4"]["occupancy"] == 1.0


def test_force_drain_flushes_open_window():
    clock = FakeClock()
    eng = _engine(window_s=60.0, clock=clock)
    eng.submit(_rand((3, 2, 8, 8)))
    assert eng.drain() == 0
    assert eng.drain(force=True) == 1       # end-of-trace flush
    assert "b4" in eng.report()["buckets"]  # 3 rows pad to bucket 4


def test_pad_to_bucket_parity_with_unpadded_execution():
    """A padded+sliced bucketed result must equal running the request
    through a network planned for its exact (unpadded) shape."""
    eng = _engine()
    x = _rand((3, 2, 8, 8), seed=7)
    rid = eng.submit(x)
    eng.drain(force=True)                   # 3 rows -> bucket 4 (padded)
    y = eng.results[rid]
    assert y.shape[0] == 3

    net = plan_network(_layers(3), backend="fft-xla")
    prepared = net.prepare(_params(), weights_version=0)
    h = x
    for name in net.layer_names:
        h = prepared[name](h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_fifo_coalescing_packs_same_image_requests():
    eng = _engine()
    rids = [eng.submit(_rand((2, 2, 8, 8), seed=i)) for i in range(2)]
    assert eng.drain() == 1                 # 2+2 rows -> ONE b4 batch
    rep = eng.report()
    assert rep["buckets"]["b4"]["n_batches"] == 1
    assert rep["buckets"]["b4"]["n_requests"] == 2
    assert rep["occupancy"] == 1.0
    assert all(eng.results[r].shape[0] == 2 for r in rids)


def test_pad_max_baseline_never_coalesces():
    eng = _engine(mode="pad-max")
    for i in range(3):
        eng.submit(_rand((1, 2, 8, 8), seed=i))
    assert eng.drain(force=True) == 3       # one request per batch
    rep = eng.report()
    assert rep["buckets"]["b4"]["n_batches"] == 3
    assert rep["occupancy"] == pytest.approx(3 / 12)


def test_replan_baseline_pays_plan_misses_on_hot_path():
    clear_plan_cache()
    eng = _engine(mode="replan")
    for b in (1, 3, 1):
        eng.submit(_rand((b, 2, 8, 8), seed=b))
    eng.drain(force=True)
    rep = eng.report()
    # two distinct shapes planned on the hot path; the repeat hits
    assert rep["plan_cache_misses_after_warmup"] > 0


def test_bucketed_zero_plan_misses_after_warmup():
    eng = _engine()
    trace = synthetic_trace(n_requests=12, max_batch=4, rate_rps=1.0,
                            seed=0)
    rep = run_trace(eng, trace, realtime=False,
                    make_input=lambda b, img: _rand((b, 2, 8, 8), b))
    assert rep["plan_cache_misses_after_warmup"] == 0
    assert rep["n_requests"] == 12


def test_replica_round_robin_fairness():
    eng = _engine(policy=BucketPolicy(max_batch=2), replicas=2)
    for i in range(8):
        eng.submit(_rand((2, 2, 8, 8), seed=i))
    eng.drain(force=True)
    rep = eng.report()
    assert rep["replica_batches"] == [4, 4]
    assert rep["n_requests"] == 8


def test_prepared_cache_dedupe_across_engine_builds():
    """A second engine over the same params/policy re-plans and
    re-prepares entirely out of the shared caches: zero new plan misses,
    one prepared-cache hit per (bucket, layer)."""
    clear_plan_cache()
    clear_prepared_cache()
    params = _params()
    policy = BucketPolicy(max_batch=4)
    ServeEngine(_layers, params, policy=policy, backend="fft-xla")
    plan_misses = plan_cache_info().misses
    hits_before = prepared_cache_info().hits

    eng2 = ServeEngine(_layers, params, policy=policy, backend="fft-xla")
    assert plan_cache_info().misses == plan_misses
    n_buckets = len(policy.batch_buckets())
    assert prepared_cache_info().hits >= hits_before + 2 * n_buckets
    assert eng2.report()["plan_cache_misses_after_warmup"] == 0


def test_update_weights_invalidates_once_per_bucket():
    eng = _engine(policy=BucketPolicy(max_batch=2))
    x = _rand((1, 2, 8, 8), seed=3)
    rid = eng.submit(x)
    eng.drain(force=True)
    y_old = np.asarray(eng.results[rid])

    new = {k: v * 2.0 for k, v in _params().items()}
    eng.update_weights(new, weights_version=1)
    rid2 = eng.submit(x)
    eng.drain(force=True)
    y_new = np.asarray(eng.results[rid2])
    assert not np.allclose(y_old, y_new)    # new weights took effect
    assert eng.report()["plan_cache_misses_after_warmup"] == 0


# --------------------------------------------------------------------------
# Trace + bench rows
# --------------------------------------------------------------------------

def test_synthetic_trace_is_deterministic_and_in_range():
    a = synthetic_trace(n_requests=16, max_batch=8, rate_rps=5.0, seed=3)
    b = synthetic_trace(n_requests=16, max_batch=8, rate_rps=5.0, seed=3)
    assert a == b and len(a) == 16
    assert all(1 <= tr.batch <= 8 for tr in a)
    assert all(a[i].t < a[i + 1].t for i in range(len(a) - 1))
    c = synthetic_trace(n_requests=16, max_batch=8, rate_rps=5.0, seed=4)
    assert c != a


def test_realtime_trace_replay_sleeps_to_offsets():
    eng = _engine(policy=BucketPolicy(max_batch=2))
    slept = []
    trace = (TraceRequest(t=0.05, batch=1), TraceRequest(t=0.10, batch=2))
    rep = run_trace(eng, trace, realtime=True, sleep=slept.append,
                    make_input=lambda b, img: _rand((b, 2, 8, 8), b))
    assert rep["n_requests"] == 2
    assert len(slept) >= 1 and all(dt > 0 for dt in slept)


def test_bench_rows_schema_valid_with_percentiles():
    from benchmarks.bench_schema import normalize
    eng = _engine()
    trace = synthetic_trace(n_requests=8, max_batch=4, rate_rps=1.0,
                            seed=1)
    run_trace(eng, trace, realtime=False,
              make_input=lambda b, img: _rand((b, 2, 8, 8), b))
    rows = normalize(eng.bench_rows())
    labels = {n.split("/")[1] for n in rows}
    assert labels <= {"b1", "b2", "b4"} and rows
    for name, entry in rows.items():
        metric = name.split("/")[2]
        assert metric in ("p50", "p99", "occupancy")
        if metric != "occupancy":
            assert entry["percentiles"]["p99"] >= \
                entry["percentiles"]["p50"]
        assert entry["config"]["mode"] == "bucketed"


# --------------------------------------------------------------------------
# netplan bucket helpers
# --------------------------------------------------------------------------

def test_plan_network_buckets_dedupe_report():
    nets = plan_network(_layers, buckets=(1, 2, 4), backend="fft-xla")
    assert isinstance(nets, BucketedNetworkPlan)
    assert tuple(nets) == (1, 2, 4)
    rep = nets.report()
    assert rep["n_buckets"] == 3
    assert rep["n_layer_plans"] == 6
    # distinct batch -> distinct plans; within a bucket s2's geometry is
    # unique too, so no cross-bucket dedupe in this net
    assert rep["n_distinct_plans"] == 6
    with pytest.raises(ValueError, match="duplicate"):
        plan_network(_layers, buckets=(2, 2), backend="fft-xla")
    # a callable layer factory needs buckets=
    with pytest.raises(TypeError, match="buckets"):
        plan_network(_layers, backend="fft-xla")


def test_bucket_shims_warn_but_work():
    from repro.conv import (bucket_report, plan_network_buckets,
                            prepare_network_buckets)
    with pytest.warns(DeprecationWarning, match="plan_network_buckets"):
        nets = plan_network_buckets(_layers, (1, 2), backend="fft-xla")
    assert tuple(nets) == (1, 2)
    with pytest.warns(DeprecationWarning, match="bucket_report"):
        rep = bucket_report(nets)
    assert rep["n_buckets"] == 2
    with pytest.warns(DeprecationWarning, match="prepare_network_buckets"):
        prepared = prepare_network_buckets(nets, _params(),
                                           weights_version=0)
    assert tuple(prepared) == (1, 2)
    with pytest.warns(DeprecationWarning, match="prepare_all"):
        nets[1].prepare_all(_params(), weights_version=0)
