"""Measured autotuner: cache hit/miss/invalidation, ``backend="tuned"``
parity with ``"auto"``, and block-override plumbing into the Pallas
cgemm/dft_tile kernel ops."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import (
    Epilogue, TunedConfig, autotune, autotune_info, clear_plan_cache,
    plan_conv, plan_network, NetworkConv,
)
from repro.core import conv2d_direct

X_SHAPE = (1, 4, 16, 16)
K_SHAPE = (8, 4, 3, 3)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


@pytest.fixture
def tune_env(tmp_path, monkeypatch):
    """Isolated tuning cache + small budget; engine caches cleared."""
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE_BUDGET_MS", "400")
    monkeypatch.setenv("REPRO_AUTOTUNE_REPS", "1")
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    autotune.reset()
    clear_plan_cache()
    yield path
    autotune.reset()
    clear_plan_cache()


# --------------------------------------------------------------------------
# Cache semantics
# --------------------------------------------------------------------------

def test_tune_miss_then_hit_and_persistence(tune_env):
    w1 = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert w1.source == "measured" and w1.us_per_call > 0
    info = autotune_info()
    assert info.misses == 1 and info.hits == 0 and info.measured == 1
    assert os.path.exists(tune_env)

    w2 = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert w2 == w1                              # in-memory hit
    assert autotune_info().hits == 1

    # round-trip: drop the in-memory store, reload from disk, same winner
    autotune.reset()
    w3 = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert w3 == w1
    info = autotune_info()
    assert info.hits == 1 and info.misses == 0 and info.measured == 0


def test_cache_file_schema(tune_env):
    autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    raw = json.load(open(tune_env))
    assert raw["version"] == autotune.CACHE_VERSION
    (key, entry), = raw["entries"].items()
    assert f"dev={autotune._device_kind()}" in key
    assert f"jax={jax.__version__}" in key
    assert entry["source"] == "measured"
    assert TunedConfig.from_json(entry).backend in (
        "direct", "fft-xla", "fft-pallas")


def test_key_invalidation_on_device_kind_and_jax_version(tune_env):
    autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert autotune_info().misses == 1

    with pytest.MonkeyPatch.context() as mp:
        # a different device kind never matches the old key -> re-measure
        mp.setattr(autotune, "_device_kind", lambda: "tpu-v9")
        autotune.tune(X_SHAPE, K_SHAPE, padding=1)
        assert autotune_info().misses == 2

        # ... and a jax upgrade likewise
        mp.setattr(autotune, "_jax_version", lambda: "99.0.0")
        autotune.tune(X_SHAPE, K_SHAPE, padding=1)
        assert autotune_info().misses == 3

    # back to the real key: still warm from the first measurement
    autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert autotune_info().hits == 1


def test_spec_signature_separates_geometry_and_constraints(tune_env):
    s1 = autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1)
    assert s1 == autotune.spec_signature(X_SHAPE, K_SHAPE, padding=(1, 1))
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=0)
    assert s1 != autotune.spec_signature((2, 4, 16, 16), K_SHAPE, padding=1)
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1,
                                         schedule="local")
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1,
                                         compute_dtype=jnp.bfloat16)
    # a pin-constrained sweep must never answer for an unconstrained one
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1, bm=8)
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1,
                                         dft_bt=64)
    # kernel-transform placement changes the measured nfft pipeline
    assert s1 != autotune.spec_signature(
        X_SHAPE, K_SHAPE, padding=1, replicate_kernel_transform=True)
    # a spectrum-pinned sweep must never answer for an unconstrained one
    assert s1 != autotune.spec_signature(X_SHAPE, K_SHAPE, padding=1,
                                         spectrum="complex")


def test_corrupt_cache_file_is_tolerated(tune_env):
    tune_env.write_text("{not json!!")
    w = autotune.tune(X_SHAPE, K_SHAPE, padding=1)     # re-measures
    assert w.source == "measured"
    assert json.load(open(tune_env))["entries"]        # rewritten clean


# --------------------------------------------------------------------------
# Disabled / cold-cache fallback
# --------------------------------------------------------------------------

def test_disabled_falls_back_to_cost_model(tune_env, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    w = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert w.source == "cost-model" and w.us_per_call is None
    assert not os.path.exists(tune_env)     # fallbacks are never persisted
    assert autotune_info().fallbacks == 1

    # plan_conv(backend="tuned") resolves to exactly what "auto" picks
    p_tuned = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned")
    p_auto = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="auto")
    assert (p_tuned.backend, p_tuned.schedule) \
        == (p_auto.backend, p_auto.schedule)
    x, k = _rand(X_SHAPE), _rand(K_SHAPE, 1)
    np.testing.assert_allclose(p_tuned(x, k), p_auto(x, k), rtol=0, atol=0)


def test_fallback_plan_is_not_frozen_in(tune_env, monkeypatch):
    """A cost-model fallback must not be memoized under the tuned key:
    once the tuning cache warms, the next plan adopts the winner."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    p_cold = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned")
    assert p_cold.backend == "direct"          # cost-model pick
    # the cache warms (e.g. serve --tune on this machine, or measurement
    # re-enabled) with a different winner...
    autotune.seed(X_SHAPE, K_SHAPE,
                  TunedConfig("fft-xla", "local", source="seeded"),
                  padding=(1, 1))
    # ...and the very next tuned plan picks it up — no stale memoization
    p_warm = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned")
    assert p_warm.backend == "fft-xla"


def test_pinned_tune_does_not_poison_unpinned_cache(tune_env):
    """tune(bm=8) keys separately from tune(); plan-level pins overlay
    the unconstrained winner instead of constraining the sweep."""
    w_pinned = autotune.tune(X_SHAPE, K_SHAPE, padding=1, bm=8, bn=8, bk=8)
    w_free = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert autotune_info().misses == 2         # distinct cache entries
    assert w_pinned.source == w_free.source == "measured"
    assert autotune.cache_key(X_SHAPE, K_SHAPE, padding=(1, 1), bm=8) \
        != autotune.cache_key(X_SHAPE, K_SHAPE, padding=(1, 1))


def test_disabled_still_serves_warm_cache(tune_env, monkeypatch):
    w1 = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    autotune.reset()
    w2 = autotune.tune(X_SHAPE, K_SHAPE, padding=1)
    assert w2 == w1 and autotune_info().hits == 1


# --------------------------------------------------------------------------
# backend="tuned" through the planner
# --------------------------------------------------------------------------

def test_tuned_plan_resolves_and_matches_oracle(tune_env):
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned")
    assert plan.backend in ("direct", "fft-xla", "fft-pallas")
    assert plan.schedule == "local"
    x, k = _rand(X_SHAPE), _rand(K_SHAPE, 1)
    np.testing.assert_allclose(plan(x, k),
                               conv2d_direct(x, k, padding=(1, 1)),
                               atol=2e-4)


@pytest.mark.parametrize("backend,schedule", [
    ("direct", "local"), ("fft-xla", "local"), ("fft-pallas", "local"),
    ("fft-xla", "nfft"), ("fft-xla", "wfft"),
    ("fft-pallas", "nfft"), ("fft-pallas", "wfft"),
])
def test_tuned_parity_with_auto_for_every_pair(tune_env, backend, schedule):
    """Whatever pair the tuner crowns, execution must match ``auto``'s
    numerics: seed the cache with each pair as the winner and compare."""
    mesh = make_mesh((1, 1), ("data", "model")) \
        if schedule in ("nfft", "wfft") else None
    autotune.seed(X_SHAPE, K_SHAPE,
                  TunedConfig(backend, schedule, source="seeded"),
                  padding=(1, 1), mesh=mesh)
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned",
                     mesh=mesh)
    assert (plan.backend, plan.schedule) == (backend, schedule)
    auto = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="auto",
                     mesh=mesh)
    x, k = _rand(X_SHAPE), _rand(K_SHAPE, 1)
    np.testing.assert_allclose(plan(x, k), auto(x, k), atol=2e-4)


def test_tuned_plan_carries_seeded_blocks(tune_env):
    autotune.seed(X_SHAPE, K_SHAPE,
                  TunedConfig("fft-pallas", "local", bm=16, bn=16, bk=8,
                              dft_bt=32, source="seeded"),
                  padding=(1, 1))
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned")
    assert (plan.backend, plan.bm, plan.bn, plan.bk, plan.dft_bt) \
        == ("fft-pallas", 16, 16, 8, 32)


def test_tuned_oversize_kernel_goes_direct(tune_env):
    plan = plan_conv((1, 2, 32, 32), (2, 2, 20, 20), backend="tuned")
    assert plan.backend == "direct"
    assert autotune_info() == (0, 0, 0, 0)     # no tuner involvement


def test_explicit_blocks_beat_tuned_blocks(tune_env):
    autotune.seed(X_SHAPE, K_SHAPE,
                  TunedConfig("fft-pallas", "local", bm=64, bn=64, bk=64,
                              source="seeded"),
                  padding=(1, 1))
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="tuned", bm=8)
    assert plan.bm == 8 and plan.bn == 64      # pin wins, rest tuned


# --------------------------------------------------------------------------
# Block-override plumbing into the kernel ops
# --------------------------------------------------------------------------

def test_resolve_blocks_defaults_and_validation():
    from repro.kernels.cgemm import default_blocks, resolve_blocks
    # heuristic defaults round UP; the resolver shrinks them to fit the
    # dim (same grid-step count, at most one lane of padding) so padding
    # is applied once, not re-grown at every stage
    assert default_blocks(100, 24, 3) == (128, 32, 8)
    assert resolve_blocks(100, 24, 3) == (104, 24, 8)
    assert resolve_blocks(128, 32, 8) == (128, 32, 8)  # exact fit: verbatim
    # explicit pins are honored verbatim; unpinned dims still shrink
    assert resolve_blocks(100, 24, 3, bm=16, bk=64) == (16, 24, 64)
    for bad in (0, -8, 2.5, True, "16"):
        with pytest.raises(ValueError, match="positive int"):
            resolve_blocks(100, 24, 3, bn=bad)


def test_resolve_bt_defaults_clamp_and_validation():
    from repro.kernels.dft_tile import DEFAULT_BT, resolve_bt
    # default shrinks to fit: same step count as DEFAULT_BT, balanced
    assert resolve_bt(1000) == 250
    assert resolve_bt(DEFAULT_BT) == DEFAULT_BT
    assert resolve_bt(10) == 10                # smaller than the default
    assert resolve_bt(1000, 64) == 64          # explicit pin: verbatim
    assert resolve_bt(48, 64) == 48            # ... clamped to tile count
    for bad in (0, -1, True, 1.5):
        with pytest.raises(ValueError, match="positive int"):
            resolve_bt(100, bad)


def test_plan_blocks_reach_cgemm_kernel(tune_env, monkeypatch):
    from repro.kernels import cgemm as cgemm_mod
    seen = {}
    real = cgemm_mod.cgemm_pallas

    def spy(Dr, Di, Gr, Gi, **kw):
        seen.update(bm=kw.get("bm"), bn=kw.get("bn"), bk=kw.get("bk"))
        return real(Dr, Di, Gr, Gi, **kw)

    monkeypatch.setattr(cgemm_mod, "cgemm_pallas", spy)
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="fft-pallas",
                     bm=16, bn=8, bk=8, cache=False)
    y = plan(_rand(X_SHAPE), _rand(K_SHAPE, 1))
    jax.block_until_ready(y)
    assert (seen["bm"], seen["bn"], seen["bk"]) == (16, 8, 8)


def test_plan_dft_bt_reaches_fused_inverse(tune_env, monkeypatch):
    from repro.kernels import dft_tile as dft_mod
    seen = {}
    real = dft_mod.tile_irfft_epilogue_pallas

    def spy(Zr, Zi, bias, **kw):
        seen["bt"] = kw.get("bt")
        return real(Zr, Zi, bias, **kw)

    monkeypatch.setattr(dft_mod, "tile_irfft_epilogue_pallas", spy)
    plan = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="fft-pallas",
                     dft_bt=32, cache=False,
                     epilogue=Epilogue(bias=True, activation="relu"))
    y = plan(_rand(X_SHAPE), _rand(K_SHAPE, 1), bias=_rand((K_SHAPE[0],), 2))
    jax.block_until_ready(y)
    assert seen["bt"] == 32


def test_block_overrides_keep_numerics():
    clear_plan_cache()
    x, k = _rand(X_SHAPE), _rand(K_SHAPE, 1)
    base = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="fft-pallas",
                     cache=False)(x, k)
    odd = plan_conv(X_SHAPE, K_SHAPE, padding=1, backend="fft-pallas",
                    bm=8, bn=8, bk=8, dft_bt=16, cache=False)(x, k)
    np.testing.assert_allclose(base, odd, atol=1e-4)


# --------------------------------------------------------------------------
# Candidate generation + network sweep
# --------------------------------------------------------------------------

def test_candidates_cover_the_space_and_order_cheap_first(tune_env):
    spec = autotune._make_spec(X_SHAPE, K_SHAPE, (1, 1), 16)
    local = autotune.candidates(spec)
    assert all(c.schedule == "local" for c in local)
    assert {c.backend for c in local} \
        == {"direct", "fft-xla", "fft-pallas"}
    assert local[0].backend != "fft-pallas"    # interpret mode goes last
    assert any(c.dft_bt for c in local)        # dft_tile tile is an axis

    mesh = make_mesh((1, 1), ("data", "model"))
    sharded = autotune.candidates(spec, mesh=mesh)
    assert {c.schedule for c in sharded} == {"nfft", "wfft"}
    assert "direct" not in {c.backend for c in sharded}

    pinned = autotune.candidates(spec, bm=8, bn=8, bk=8, dft_bt=32)
    assert all((c.bm, c.dft_bt) == (8, 32)
               for c in pinned if c.backend == "fft-pallas")


def test_candidates_spectrum_axis(tune_env):
    spec = autotune._make_spec(X_SHAPE, K_SHAPE, (1, 1), 16)
    local = autotune.candidates(spec)
    # FFT backends get both frequency layouts; direct has no spectrum
    for be in ("fft-xla", "fft-pallas"):
        assert {c.spectrum for c in local if c.backend == be} \
            == {"real", "complex"}
    assert all(c.spectrum == "real" for c in local if c.backend == "direct")
    assert local[0].spectrum == "real"         # cost-model pick stays first
    # pinning the spectrum collapses the axis (and drops direct for the
    # complex-only sweep — plan_conv rejects direct+complex)
    pinned = autotune.candidates(spec, spectrum="complex")
    assert {c.spectrum for c in pinned} == {"complex"}
    assert "direct" not in {c.backend for c in pinned}


def test_plan_network_tuned_sweep_and_report(tune_env):
    layers = [
        NetworkConv("c1", X_SHAPE, K_SHAPE, padding=1),
        NetworkConv("c2", X_SHAPE, K_SHAPE, padding=1),   # same geometry
    ]
    net = plan_network(layers, backend="tuned")
    # one sweep: the duplicate geometry was tuned once, not twice
    assert autotune_info().misses == 1 and autotune_info().hits >= 0
    rep = net.tuning_report()
    assert set(rep) == {"c1", "c2"}
    for r in rep.values():
        assert r["source"] == "measured"
        assert r["us_per_call"] > 0
        assert r["backend"] in ("direct", "fft-xla", "fft-pallas")
