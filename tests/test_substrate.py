"""Substrate tests: optimizer, data determinism, checkpoint/restart,
fault tolerance, roofline HLO parsing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, requires_hypothesis

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data import DataConfig, lm_batch, image_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train import make_train_step, init_train_state, cross_entropy
import repro.checkpoint as ckpt
from repro.launch.roofline import parse_collectives, roofline_terms


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=0, total_steps=1,
                      min_lr_frac=1.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st_ = adamw_init(p)
    new_p, st_, _ = adamw_update(g, st_, p, cfg)
    # reference AdamW step 1
    m = 0.1 * np.asarray([0.5, 0.25])
    v = 0.01 * np.asarray([0.25, 0.0625])
    mh, vh = m / 0.1, v / 0.01
    ref = np.asarray([1.0, -2.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=1,
                      min_lr_frac=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": 100.0 * jnp.ones((4,))}
    _, _, m = adamw_update(g, adamw_init(p), p, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


if HAVE_HYPOTHESIS:
    @requires_hypothesis
    @settings(max_examples=20, deadline=None)
    @given(step=st.integers(0, 9999))
    def test_cosine_schedule_bounds(step):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=10000,
                          min_lr_frac=0.1)
        lr = float(cosine_lr(cfg, jnp.int32(step)))
        assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)
        if step >= cfg.warmup_steps:
            assert lr >= cfg.lr * cfg.min_lr_frac * (1 - 1e-6)
else:
    @requires_hypothesis
    def test_cosine_schedule_bounds():
        pass


def test_cross_entropy_reference():
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((2, 3, 7)), jnp.float32)
    labels = jnp.asarray([[1, 2, 3], [0, 6, 5]], jnp.int32)
    ce = cross_entropy(logits, labels, z_loss=0.0)
    lp = jax.nn.log_softmax(logits)
    ref = -np.mean([lp[b, s, labels[b, s]] for b in range(2)
                    for s in range(3)])
    assert float(ce) == pytest.approx(float(ref), rel=1e-5)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    dc = DataConfig(vocab=100, seq_len=17, global_batch=4, seed=7)
    b1, b2 = lm_batch(dc, 5), lm_batch(dc, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(dc, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


if HAVE_HYPOTHESIS:
    @requires_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(step=st.integers(0, 10000), seed=st.integers(0, 100))
    def test_data_tokens_in_range(step, seed):
        dc = DataConfig(vocab=64, seq_len=9, global_batch=2, seed=seed)
        b = lm_batch(dc, step)
        t = np.asarray(b["tokens"])
        assert t.min() >= 0 and t.max() < 64
else:
    @requires_hypothesis
    def test_data_tokens_in_range():
        pass


# --------------------------------------------------------------------------
# checkpoint / fault tolerance
# --------------------------------------------------------------------------

def _tiny_train(steps, params, opt, step_fn, dc, start=0):
    for i in range(start, steps):
        params, opt, m = step_fn(params, opt, lm_batch(dc, i))
    return params, opt, float(m["loss"])


def test_crash_restart_is_bit_exact():
    cfg = get_config("qwen3-14b", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=17, global_batch=4, seed=1)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    params, opt, _ = _tiny_train(6, params, opt, step_fn, dc)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 6, {"p": params, "o": opt})
        # continue uninterrupted
        pa, oa, loss_a = _tiny_train(10, params, opt, step_fn, dc, start=6)
        # "crash" + restore + continue
        state, meta = ckpt.restore(d, 6, {"p": params, "o": opt})
        pb, ob, loss_b = _tiny_train(10, state["p"], state["o"], step_fn,
                                     dc, start=6)
    assert loss_a == loss_b
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint_and_latest():
    with tempfile.TemporaryDirectory() as d:
        t = ckpt.save_async(d, 3, {"x": jnp.arange(5)})
        t.join()
        ckpt.save(d, 7, {"x": jnp.arange(5) * 2})
        assert ckpt.latest_step(d) == 7
        state, meta = ckpt.restore(d, 7, {"x": jnp.zeros(5, jnp.int32)})
        np.testing.assert_array_equal(np.asarray(state["x"]),
                                      np.arange(5) * 2)


def test_atomic_commit_ignores_partial(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"x": jnp.ones(3)})
    # simulate a crash mid-write: stray .tmp dir must be ignored
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


# --------------------------------------------------------------------------
# roofline HLO parsing
# --------------------------------------------------------------------------

_FAKE_HLO = """
HloModule m

%cond.1 (a: s32[]) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(s32[] %a, s32[] %c), direction=LT
}

%body.2 (a: s32[]) -> s32[] {
  %ar = f32[4,8]{1,0} all-reduce(f32[4,8]{1,0} %x), replica_groups={}
  ROOT %n = s32[] add(s32[] %a, s32[] %one)
}

ENTRY %main () -> f32[] {
  %ag = bf16[2,2]{1,0} all-gather(bf16[1,2]{1,0} %p), dimensions={0}
  %w = s32[] while(s32[] %z), condition=%cond.1, body=%body.2
  ROOT %r = f32[] constant(0)
}
"""


def test_parse_collectives_counts_loop_trips():
    out = parse_collectives(_FAKE_HLO)
    # all-gather once: 2*2*2 = 8 bytes; all-reduce inside while x10:
    # 4*8*4 = 128 bytes * 10 = 1280
    assert out["bytes"]["all-gather"] == 8
    assert out["bytes"]["all-reduce"] == 1280
    assert out["total_bytes"] == 1288


def test_roofline_terms_dominance():
    t = roofline_terms(197e12 * 2, 819e9, 50e9 * 3)
    assert t["dominant"] == "collective"
    assert t["bound_s"] == pytest.approx(3.0)
