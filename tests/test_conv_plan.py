"""Plan/execute conv engine: cache semantics, registry validation,
(backend, schedule) equivalence grid, and the cost-model auto crossover."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import (
    analyze, plan_conv, conv2d, plan_cache_info, clear_plan_cache,
    plan_cache_capacity, available_backends, available_schedules,
    register_backend,
)
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


# --------------------------------------------------------------------------
# Plan cache
# --------------------------------------------------------------------------

def test_plan_cache_hit_and_reuse():
    clear_plan_cache()
    p1 = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=1)
    info = plan_cache_info()
    assert info.misses == 1 and info.hits == 0 and info.size == 1
    p2 = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=1)
    assert p2 is p1                       # same frozen object, not a copy
    assert plan_cache_info().hits == 1
    # different geometry -> different plan, new cache entry
    p3 = plan_conv((2, 3, 16, 16), (4, 3, 5, 5), padding=1)
    assert p3 is not p1
    assert plan_cache_info() == (1, 2, 2)
    # padding normalization: int 1 and (1, 1) share a key
    p4 = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=(1, 1))
    assert p4 is p1
    # cache=False bypasses
    p5 = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=1, cache=False)
    assert p5 is not p1 and p5 == p1
    clear_plan_cache()
    assert plan_cache_info() == (0, 0, 0)


def test_plan_cache_is_lru_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_CONV_PLAN_CACHE_SIZE", "4")
    assert plan_cache_capacity() == 4
    clear_plan_cache()
    plans = [plan_conv((1, 2, 8 + i, 8), (2, 2, 3, 3)) for i in range(6)]
    assert plan_cache_info().size == 4          # two oldest evicted
    # newest entries still hit...
    assert plan_conv((1, 2, 13, 8), (2, 2, 3, 3)) is plans[5]
    assert plan_cache_info().hits == 1
    # ...the evicted oldest re-plans (miss, equal-but-new object)
    p0 = plan_conv((1, 2, 8, 8), (2, 2, 3, 3))
    assert p0 == plans[0] and p0 is not plans[0]
    clear_plan_cache()


def test_plan_cache_keys_mesh_by_value():
    """Two equal meshes (same axes/devices) must share one cache entry."""
    clear_plan_cache()
    mesh_a = make_mesh((1, 1), ("data", "model"))
    mesh_b = make_mesh((1, 1), ("data", "model"))
    pa = plan_conv((1, 2, 8, 8), (2, 2, 3, 3), mesh=mesh_a)
    pb = plan_conv((1, 2, 8, 8), (2, 2, 3, 3), mesh=mesh_b)
    assert pb is pa
    assert plan_cache_info() == (1, 1, 1)
    clear_plan_cache()


# --------------------------------------------------------------------------
# Registry validation
# --------------------------------------------------------------------------

def test_registry_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown conv backend"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), backend="nope")
    with pytest.raises(ValueError, match="unknown conv schedule"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), schedule="nope")


def test_registry_validates_combinations():
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="requires a mesh"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), schedule="nfft")
    with pytest.raises(ValueError, match="ignores the mesh"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), schedule="local", mesh=mesh)
    with pytest.raises(ValueError, match="does not support schedule"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), backend="direct",
                  schedule="nfft", mesh=mesh)
    with pytest.raises(ValueError, match="channel mismatch"):
        plan_conv((1, 2, 8, 8), (2, 3, 3, 3))
    with pytest.raises(ValueError, match="no axis"):
        plan_conv((1, 2, 8, 8), (2, 2, 3, 3), schedule="nfft", mesh=mesh,
                  model_axis="tensor")


def test_registry_accepts_custom_backend():
    calls = []

    def _exec(plan, x, k):
        calls.append(plan.backend)
        return conv2d_direct(x, k, padding=plan.padding)

    register_backend("test-direct", _exec, schedules=("local",))
    assert "test-direct" in available_backends()
    x, k = _rand((1, 2, 8, 8), 1), _rand((2, 2, 3, 3), 2)
    y = plan_conv(x.shape, k.shape, padding=1, backend="test-direct")(x, k)
    assert calls == ["test-direct"]
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(conv2d_direct(x, k, padding=1)))


def test_plan_rejects_mismatched_shapes():
    plan = plan_conv((2, 3, 16, 16), (4, 3, 3, 3), padding=1)
    x, k = _rand((2, 3, 16, 16)), _rand((4, 3, 3, 3))
    with pytest.raises(ValueError, match="plan was built for input"):
        plan(x[:1], k)
    with pytest.raises(ValueError, match="plan was built for kernel"):
        plan(x, k[:2])


# --------------------------------------------------------------------------
# (backend, schedule) equivalence grid vs the direct oracle
# --------------------------------------------------------------------------

CASES = [
    # B, C, Co, H, W, kh, kw, pad, delta
    (2, 3, 4, 20, 20, 3, 3, 1, 16),
    (1, 4, 2, 17, 23, 5, 5, 2, 16),
    (2, 2, 2, 12, 12, 3, 3, 1, 8),
]
LOCAL_PAIRS = [("direct", "local"), ("fft-xla", "local"),
               ("fft-pallas", "local")]
SHARDED_PAIRS = [("fft-xla", "nfft"), ("fft-xla", "wfft"),
                 ("fft-pallas", "nfft"), ("fft-pallas", "wfft")]


@pytest.mark.parametrize("backend,schedule", LOCAL_PAIRS + SHARDED_PAIRS)
@pytest.mark.parametrize("case", CASES, ids=lambda c: "x".join(map(str, c)))
def test_backend_schedule_equivalence(backend, schedule, case):
    B, C, Co, H, W, kh, kw, pad, delta = case
    x, k = _rand((B, C, H, W), 1), _rand((Co, C, kh, kw), 2)
    kwargs = dict(padding=pad, delta=delta, backend=backend,
                  schedule=schedule)
    if schedule != "local":
        # degenerate 1x1 mesh: same collective program, single real device
        kwargs["mesh"] = make_mesh((1, 1), ("data", "model"))
    y = plan_conv(x.shape, k.shape, **kwargs)(x, k)
    y0 = conv2d_direct(x, k, padding=pad)
    assert y.shape == y0.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=3e-4, atol=3e-4)


def test_asymmetric_padding_all_backends():
    """(pad_h, pad_w) means symmetric-per-axis everywhere (regression:
    conv2d_direct used to read it as lax (lo, hi) on both dims)."""
    x, k = _rand((1, 2, 10, 10), 11), _rand((2, 2, 3, 3), 12)
    plans = [plan_conv(x.shape, k.shape, padding=(1, 2), backend=be)
             for be in ("direct", "fft-xla", "fft-pallas")]
    ys = [np.asarray(p(x, k)) for p in plans]
    assert all(p.out_shape == (1, 2, 10, 12) for p in plans)
    for y in ys:
        assert y.shape == (1, 2, 10, 12)
        np.testing.assert_allclose(y, ys[0], rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("schedule", ["nfft", "wfft"])
def test_compute_dtype_reaches_hot_stage(schedule):
    """Regression: plan_conv(schedule="wfft", compute_dtype=bf16) used to be
    silently dropped.  Both sharded schedules must now cast the CGEMM
    operands — certified by the analyzer's dtype-flow facts: the cast must
    land on the CGEMM operands AND before the hot collective — and stay
    near the f32 result (f32 accumulation)."""
    mesh = make_mesh((1, 1), ("data", "model"))
    x, k = _rand((2, 4, 16, 16), 21), _rand((4, 4, 3, 3), 22)
    plan_bf16 = plan_conv(x.shape, k.shape, padding=1, schedule=schedule,
                          mesh=mesh, compute_dtype=jnp.bfloat16)
    profile = analyze(plan_bf16)
    assert profile.cgemm_dtypes == ("bfloat16",), \
        f"{schedule}: compute_dtype never reached the hot stage"
    hot = "psum" if schedule == "wfft" else "all_to_all"
    assert profile.collective_dtypes[hot].get("bfloat16", 0) >= 2, \
        f"{schedule}: cast landed after the hot collective"
    profile.check().raise_if_failed()
    y16 = plan_bf16(x, k)
    y32 = plan_conv(x.shape, k.shape, padding=1, schedule=schedule,
                    mesh=mesh)(x, k)
    assert y16.dtype == x.dtype
    rel = float(jnp.max(jnp.abs(y16 - y32))) / float(jnp.max(jnp.abs(y32)))
    assert rel < 0.05, f"{schedule}: bf16 hot stage diverged ({rel})"


def test_compute_dtype_honored_by_direct_backend():
    """Regression (same bug class as the wfft drop): compute_dtype must not
    be silently ignored when the plan resolves to the direct backend.
    direct is an opaque backend (no stage hooks for the analyzer to read),
    so the bf16 evidence here is numeric: the result must differ from f32
    but stay close (casts applied, f32 accumulated)."""
    x, k = _rand((1, 3, 16, 16), 23), _rand((4, 3, 1, 1), 24)
    plan = plan_conv(x.shape, k.shape, compute_dtype=jnp.bfloat16)
    assert plan.backend == "direct"           # tiny kernel -> cost model
    y16, y32 = plan(x, k), plan_conv(x.shape, k.shape)(x, k)
    assert y16.dtype == x.dtype
    rel = float(jnp.max(jnp.abs(y16 - y32))) / float(jnp.max(jnp.abs(y32)))
    assert 0 < rel < 0.05                     # casts applied, f32 accumulated


def test_replicate_kernel_transform_single_device():
    x, k = _rand((2, 3, 14, 14), 3), _rand((4, 3, 3, 3), 4)
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = plan_conv(x.shape, k.shape, padding=1, schedule="nfft", mesh=mesh,
                     replicate_kernel_transform=True)
    np.testing.assert_allclose(
        np.asarray(plan(x, k)),
        np.asarray(conv2d_direct(x, k, padding=1)), rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# Auto selection (cost-model crossover) and plan metadata
# --------------------------------------------------------------------------

def test_auto_backend_crossover():
    # tiny 1x1 kernel: transforms dwarf the direct cost -> direct
    small = plan_conv((1, 3, 16, 16), (4, 3, 1, 1))
    assert small.backend == "direct"
    assert small.spec.direct_flops() <= \
        small.spec.cgemm_flops(three_m=True) + small.spec.transform_flops()
    # VGG-scale 3x3 layer: FFT path is cheaper -> fft-xla
    big = plan_conv((4, 128, 56, 56), (128, 128, 3, 3), padding=1)
    assert big.backend == "fft-xla"
    assert big.spec.direct_flops() > \
        big.spec.cgemm_flops(three_m=True) + big.spec.transform_flops()
    # both execute correctly through whatever auto picked
    for plan, seed in ((small, 5), (big, 7)):
        x = _rand(plan.x_shape, seed)
        k = _rand(plan.k_shape, seed + 1)
        np.testing.assert_allclose(
            np.asarray(plan(x, k)),
            np.asarray(conv2d_direct(x, k, padding=plan.padding)),
            rtol=2e-3, atol=2e-3)


def test_oversize_kernel_routes_to_direct():
    """Kernels larger than delta are FFT-impossible but fine directly."""
    plan = plan_conv((1, 2, 32, 32), (3, 2, 17, 17), delta=16)
    assert plan.backend == "direct"
    x, k = _rand(plan.x_shape, 15), _rand(plan.k_shape, 16)
    np.testing.assert_allclose(
        np.asarray(plan(x, k)), np.asarray(conv2d_direct(x, k)),
        rtol=3e-4, atol=3e-4)
    with pytest.raises(ValueError, match="exceeds tile size"):
        plan_conv((1, 2, 32, 32), (3, 2, 17, 17), delta=16,
                  backend="fft-xla")


def test_auto_schedule_follows_mesh():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert plan_conv((1, 2, 8, 8), (2, 2, 3, 3)).schedule == "local"
    assert plan_conv((1, 2, 8, 8), (2, 2, 3, 3),
                     mesh=mesh).schedule == "nfft"


def test_plan_metadata_and_flops():
    plan = plan_conv((2, 8, 20, 20), (4, 8, 3, 3), padding=1,
                     backend="fft-xla")
    assert plan.out_shape == (2, 4, 20, 20)
    assert plan.differentiable
    assert plan.flops() == \
        plan.spec.cgemm_flops(three_m=True, spectrum=plan.spectrum) \
        + plan.spec.transform_flops()
    # the compact Hermitian layout is the default and is cheaper than the
    # historical rect rfft2 grid
    assert plan.spectrum == "real"
    assert plan.flops() < plan.spec.cgemm_flops(three_m=True) \
        + plan.spec.transform_flops()
    direct = plan_conv((2, 8, 20, 20), (4, 8, 3, 3), padding=1,
                       backend="direct")
    assert direct.flops() == direct.spec.direct_flops()
    assert "backend=fft-xla" in plan.describe()
    # differentiability is derived from the stage pipeline: every backend
    # composed over stages is differentiable on every schedule it supports.
    pallas = plan_conv((2, 8, 20, 20), (4, 8, 3, 3), padding=1,
                       backend="fft-pallas")
    assert pallas.differentiable


def test_plan_gradients_match_direct():
    x, k = _rand((2, 3, 12, 12), 5), _rand((4, 3, 3, 3), 6)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")

    def loss(f):
        return lambda x, k: jnp.sum(jnp.sin(f(x, k)))

    g1 = jax.grad(loss(plan), argnums=(0, 1))(x, k)
    g0 = jax.grad(loss(lambda x, k: conv2d_direct(x, k, padding=1)),
                  argnums=(0, 1))(x, k)
    for a, b in zip(g1, g0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_conv2d_one_shot_uses_cache():
    clear_plan_cache()
    x, k = _rand((1, 2, 10, 10), 7), _rand((2, 2, 3, 3), 8)
    y1 = conv2d(x, k, padding=1, backend="fft-xla")
    y2 = conv2d(x, k, padding=1, backend="fft-xla")
    assert plan_cache_info().hits >= 1
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
    np.testing.assert_allclose(
        np.asarray(y1), np.asarray(conv2d_direct(x, k, padding=1)),
        rtol=2e-4, atol=2e-4)


def test_plans_jit_and_registry_listing():
    assert {"direct", "fft-xla", "fft-pallas"} <= set(available_backends())
    assert {"local", "nfft", "wfft"} <= set(available_schedules())
    x, k = _rand((1, 2, 12, 12), 9), _rand((3, 2, 3, 3), 10)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")
    y = jax.jit(plan)(x, k)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(conv2d_direct(x, k, padding=1)),
        rtol=2e-4, atol=2e-4)
