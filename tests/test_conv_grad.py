"""Plan-level autodiff: jax.grad through every backend x schedule vs the
direct-conv oracle.

Differentiability is a property of the plan (one custom VJP over the stage
pipeline), so the full matrix trains: fft-pallas x local, and the nfft /
wfft sharded schedules (in-process on a degenerate 1x1 mesh; on a real
2x4 device mesh in the slow subprocess test)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import plan_conv
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _loss(f):
    return lambda x, k: jnp.sum(jnp.sin(f(x, k)))


def _oracle_grads(x, k, pad):
    return jax.grad(_loss(lambda a, b: conv2d_direct(a, b, padding=pad)),
                    argnums=(0, 1))(x, k)


@pytest.mark.parametrize("backend", ["fft-xla", "fft-pallas"])
def test_local_grads_match_oracle(backend):
    x, k = _rand((2, 3, 12, 12), 1), _rand((4, 3, 3, 3), 2)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend)
    assert plan.differentiable
    g1 = jax.grad(_loss(plan), argnums=(0, 1))(x, k)
    for a, b in zip(g1, _oracle_grads(x, k, 1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("backend", ["fft-xla", "fft-pallas"])
@pytest.mark.parametrize("schedule", ["nfft", "wfft"])
def test_sharded_grads_match_oracle_1x1(backend, schedule):
    """Degenerate 1x1 mesh: the same collective program, single device."""
    mesh = make_mesh((1, 1), ("data", "model"))
    x, k = _rand((2, 3, 14, 14), 3), _rand((4, 3, 3, 3), 4)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                     schedule=schedule, mesh=mesh)
    assert plan.differentiable
    g1 = jax.grad(_loss(plan), argnums=(0, 1))(x, k)
    for a, b in zip(g1, _oracle_grads(x, k, 1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_grads_jit_and_value_and_grad():
    x, k = _rand((1, 2, 10, 10), 5), _rand((2, 2, 3, 3), 6)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")
    v1, g1 = jax.jit(jax.value_and_grad(_loss(plan), argnums=(0, 1)))(x, k)
    v0 = _loss(lambda a, b: conv2d_direct(a, b, padding=1))(x, k)
    assert abs(float(v1) - float(v0)) < 1e-4
    for a, b in zip(g1, _oracle_grads(x, k, 1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_second_order_grads_run():
    """The VJP is defined recursively in terms of plans, so grad-of-grad
    composes (sanity: finite values, correct shape)."""
    x, k = _rand((1, 2, 10, 10), 7), _rand((2, 2, 3, 3), 8)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla")
    gg = jax.grad(lambda a: jnp.sum(
        jax.grad(lambda b: jnp.sum(plan(b, k) ** 2))(a) ** 2))(x)
    assert gg.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(gg)))


_SCRIPT_GRAD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.conv import plan_conv
from repro.core import conv2d_direct
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 8, 28, 28)), jnp.float32)
k = jnp.asarray(rng.standard_normal((8, 8, 3, 3)), jnp.float32)
loss = lambda f: (lambda x, k: jnp.sum(jnp.sin(f(x, k))))
g0 = jax.grad(loss(lambda a, b: conv2d_direct(a, b, padding=1)),
              argnums=(0, 1))(x, k)
for sched in ("nfft", "wfft"):
    plan = plan_conv(x.shape, k.shape, schedule=sched, mesh=mesh, padding=1)
    g1 = jax.jit(jax.grad(loss(plan), argnums=(0, 1)))(x, k)
    for a, b in zip(g1, g0):
        err = float(jnp.max(jnp.abs(a - b))) / float(jnp.max(jnp.abs(b)))
        assert err < 5e-4, (sched, err)
# prepared numerics before/after a weight update on the real mesh
k2 = jnp.asarray(rng.standard_normal(k.shape), jnp.float32)
plan = plan_conv(x.shape, k.shape, schedule="nfft", mesh=mesh, padding=1)
p1 = plan.prepare(k, weights_version=1)
assert plan.prepare(k, weights_version=1) is p1
p2 = plan.prepare(k2, weights_version=2)
y2 = p2(x)
err = float(jnp.max(jnp.abs(y2 - conv2d_direct(x, k2, padding=1)))) \
    / float(jnp.max(jnp.abs(y2)))
assert err < 1e-4, err
print("GRAD_DIST_OK")
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_sharded_grads_multi_device():
    out = _run(_SCRIPT_GRAD)
    assert "GRAD_DIST_OK" in out
