"""Core FFT-convolution vs the direct oracle (+ properties via hypothesis).

The property tests need ``hypothesis``; environments without it still run
the example-based tests (the property tests report as skipped).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, requires_hypothesis

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

from repro.conv import plan_conv
from repro.core import conv2d_direct, make_spec


def fft_conv2d(x, k, *, padding=0, delta=16, three_m=True):
    """Planned fft-xla conv with the old helper signature (test shorthand)."""
    return plan_conv(tuple(x.shape), tuple(k.shape), padding=padding,
                     delta=delta, three_m=three_m, backend="fft-xla")(x, k)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


CASES = [
    # B, C, Co, H, W, kh, kw, pad, delta
    (2, 3, 4, 20, 20, 3, 3, 0, 16),
    (1, 4, 2, 17, 23, 5, 5, 2, 16),
    (2, 2, 2, 14, 14, 3, 3, 1, 16),
    (1, 1, 1, 16, 16, 1, 1, 0, 16),
    (2, 3, 2, 7, 9, 3, 3, 1, 16),
    (1, 2, 3, 30, 30, 7, 7, 3, 16),
    (2, 2, 2, 12, 12, 3, 3, 1, 8),
    (1, 2, 2, 40, 40, 5, 5, 2, 32),
]


@pytest.mark.parametrize("B,C,Co,H,W,kh,kw,pad,delta", CASES)
def test_matches_direct(B, C, Co, H, W, kh, kw, pad, delta):
    x = _rand((B, C, H, W), 1)
    k = _rand((Co, C, kh, kw), 2)
    y = fft_conv2d(x, k, padding=pad, delta=delta)
    y0 = conv2d_direct(x, k, padding=pad)
    assert y.shape == y0.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("three_m", [True, False])
def test_3m_equals_4m(three_m):
    x, k = _rand((2, 4, 20, 20), 3), _rand((4, 4, 3, 3), 4)
    y = fft_conv2d(x, k, padding=1, three_m=three_m)
    y0 = conv2d_direct(x, k, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)


def test_gradients_match_direct():
    x, k = _rand((2, 3, 12, 12), 5), _rand((4, 3, 3, 3), 6)

    def loss(f):
        return lambda x, k: jnp.sum(jnp.sin(f(x, k)))

    g1 = jax.grad(loss(lambda x, k: fft_conv2d(x, k, padding=1)),
                  argnums=(0, 1))(x, k)
    g0 = jax.grad(loss(lambda x, k: conv2d_direct(x, k, padding=1)),
                  argnums=(0, 1))(x, k)
    for a, b in zip(g1, g0):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_spec_geometry():
    spec = make_spec((4, 8, 56, 56), (16, 8, 3, 3), padding=1)
    assert spec.Ho == 56 and spec.Wo == 56
    assert spec.t_h == 14 and spec.X == 4 and spec.D == 4
    assert spec.P == 16 * 9 and spec.M == 4 * 16


if HAVE_HYPOTHESIS:
    @requires_hypothesis
    @settings(max_examples=20, deadline=None)
    @given(
        B=st.integers(1, 2), C=st.integers(1, 4), Co=st.integers(1, 4),
        H=st.integers(5, 24), W=st.integers(5, 24),
        k=st.sampled_from([1, 3, 5]), pad=st.integers(0, 2),
    )
    def test_property_matches_oracle(B, C, Co, H, W, k, pad):
        if H < k or W < k:
            return
        x = _rand((B, C, H, W), H * 31 + W)
        kk = _rand((Co, C, k, k), k)
        y = fft_conv2d(x, kk, padding=pad)
        y0 = conv2d_direct(x, kk, padding=pad)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=3e-4, atol=3e-4)

    @requires_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(a=st.floats(-2, 2), b=st.floats(-2, 2))
    def test_property_linearity(a, b):
        """conv(a x1 + b x2, k) == a conv(x1, k) + b conv(x2, k)."""
        x1, x2 = _rand((1, 2, 18, 18), 7), _rand((1, 2, 18, 18), 8)
        k = _rand((3, 2, 3, 3), 9)
        lhs = fft_conv2d(a * x1 + b * x2, k, padding=1)
        rhs = a * fft_conv2d(x1, k, padding=1) \
            + b * fft_conv2d(x2, k, padding=1)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-3, atol=1e-3)
else:
    @requires_hypothesis
    def test_property_matches_oracle():
        pass

    @requires_hypothesis
    def test_property_linearity():
        pass


def test_pallas_backend_matches_direct():
    """End-to-end conv with the Pallas CGEMM kernel (interpret on CPU)."""
    x, k = _rand((2, 8, 20, 20), 11), _rand((8, 8, 3, 3), 12)
    y = plan_conv(x.shape, k.shape, padding=1, backend="fft-pallas")(x, k)
    y0 = conv2d_direct(x, k, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=3e-4, atol=3e-4)


def test_deprecated_shims_still_work():
    """Old entry points warn but route through the same planned paths."""
    import repro.core as core
    x, k = _rand((1, 3, 12, 12), 13), _rand((2, 3, 3, 3), 14)
    y0 = conv2d_direct(x, k, padding=1)
    with pytest.warns(DeprecationWarning):
        y = core.fft_conv2d(x, k, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    with pytest.warns(DeprecationWarning):
        y = core.fft_conv2d_pallas(x, k, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=3e-4, atol=3e-4)
