"""Comm/compute-overlapped sub-slab execution (``ConvPlan.overlap``).

The overlapped schedules split the batch into ``slab:<k>`` sub-slabs and
double-buffer: slab i+1's boundary collective is issued before slab i's
hot cgemm, so a latency-hiding XLA schedule can run them concurrently.
These tests certify the *semantics* are untouched — overlapped output,
prepared execution and plan-level gradients must match the sequential
(``overlap="off"``) twin and the direct oracle, on even AND odd slab
remainders — plus knob validation, plan-cache separation, and the
analyzer's overlap invariants (collective counts, bytes parity vs the
sequential twin, uniform Pallas blocks, seeded-violation negative path).

In-process tests run the full collective program on a degenerate 1x1
mesh; the real 2- and 4-way emulated-NUMA meshes (device-count forcing +
scheduler flags from ``repro.launch.env``) run in slow subprocess tests,
keeping the main pytest process single-device (conftest contract).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.conv import analyze, plan_conv
from repro.conv.analyze import seeded_violation
from repro.core import conv2d_direct


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


BACKENDS = ["fft-xla", "fft-pallas"]
SCHEDULES = ["nfft", "wfft"]


# --------------------------------------------------------------------------
# Parity: overlapped == sequential == oracle (even and odd slab remainders)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("spectrum", ["real", "complex"])
@pytest.mark.parametrize("batch", [4, 5])   # 5: odd remainder, slabs 3+2
def test_overlap_matches_sequential_and_oracle(backend, schedule,
                                               spectrum, batch):
    x, k = _rand((batch, 3, 12, 12), 1), _rand((4, 3, 3, 3), 2)
    kw = dict(padding=1, backend=backend, schedule=schedule, mesh=_mesh(),
              spectrum=spectrum)
    seq = plan_conv(x.shape, k.shape, overlap="off", **kw)
    ovl = plan_conv(x.shape, k.shape, overlap="slab:2", **kw)
    assert seq.num_slabs == 1 and ovl.num_slabs == 2
    y_seq, y_ovl = seq(x, k), ovl(x, k)
    # same stage math, same reduction order per slab -> tight parity
    np.testing.assert_allclose(np.asarray(y_ovl), np.asarray(y_seq),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_ovl),
                               np.asarray(conv2d_direct(x, k, padding=1)),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_overlap_prepared_matches_one_shot(schedule):
    x, k = _rand((5, 3, 12, 12), 3), _rand((4, 3, 3, 3), 4)
    plan = plan_conv(x.shape, k.shape, padding=1, schedule=schedule,
                     mesh=_mesh(), overlap="slab:2")
    prepared = plan.prepare(k)
    np.testing.assert_allclose(np.asarray(prepared(x)),
                               np.asarray(plan(x, k)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jax.jit(prepared)(x)),
                               np.asarray(prepared(x)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_overlap_grads_match_sequential_and_oracle(schedule):
    """The plan-level VJP transposes the overlap knob with the plan, so
    training through an overlapped schedule matches the sequential twin."""
    x, k = _rand((5, 3, 12, 12), 5), _rand((4, 3, 3, 3), 6)
    kw = dict(padding=1, backend="fft-xla", schedule=schedule, mesh=_mesh())
    seq = plan_conv(x.shape, k.shape, overlap="off", **kw)
    ovl = plan_conv(x.shape, k.shape, overlap="slab:2", **kw)
    assert ovl.differentiable

    def loss(f):
        return lambda a, b: jnp.sum(jnp.sin(f(a, b)))

    g_seq = jax.grad(loss(seq), argnums=(0, 1))(x, k)
    g_ovl = jax.grad(loss(ovl), argnums=(0, 1))(x, k)
    g_dir = jax.grad(loss(lambda a, b: conv2d_direct(a, b, padding=1)),
                     argnums=(0, 1))(x, k)
    for a, b in zip(g_ovl, g_seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    for a, b in zip(g_ovl, g_dir):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# Knob validation + plan-cache separation
# --------------------------------------------------------------------------

def test_overlap_validation_and_normalization():
    shp = ((4, 3, 12, 12), (4, 3, 3, 3))
    with pytest.raises(ValueError, match="unknown overlap"):
        plan_conv(*shp, padding=1, schedule="nfft", mesh=_mesh(),
                  overlap="slabs:2")
    with pytest.raises(ValueError, match="unknown overlap"):
        plan_conv(*shp, padding=1, schedule="nfft", mesh=_mesh(),
                  overlap="slab:x")
    # local schedules have no boundary collective to overlap
    with pytest.raises(ValueError, match="sharded stage-pipeline"):
        plan_conv(*shp, padding=1, backend="fft-xla", overlap="slab:2")
    with pytest.raises(ValueError, match="sharded stage-pipeline"):
        plan_conv(*shp, padding=1, backend="direct", overlap="slab:2")
    # slab:1 never exists — it normalizes to off (and off is always legal)
    p = plan_conv(*shp, padding=1, backend="fft-xla", overlap="off")
    assert p.overlap == "off" and p.num_slabs == 1
    # an oversize slab count clamps once to the per-rank batch
    p = plan_conv(*shp, padding=1, schedule="nfft", mesh=_mesh(),
                  overlap="slab:8")
    assert p.overlap == "slab:4" and p.num_slabs == 4


def test_overlap_auto_resolution():
    mesh = _mesh()
    # enough per-rank batch: auto engages slab:2
    p = plan_conv((4, 3, 12, 12), (4, 3, 3, 3), padding=1, schedule="nfft",
                  mesh=mesh, overlap="auto")
    assert p.overlap == "slab:2"
    # tiny batch: slabbing 1-row slabs cannot amortize latency -> off
    p = plan_conv((2, 3, 12, 12), (4, 3, 3, 3), padding=1, schedule="nfft",
                  mesh=mesh, overlap="auto")
    assert p.overlap == "off"
    # local plans resolve auto to off instead of raising
    p = plan_conv((4, 3, 12, 12), (4, 3, 3, 3), padding=1,
                  backend="fft-xla", overlap="auto")
    assert p.overlap == "off"


def test_overlap_is_part_of_the_plan_cache_key():
    shp = ((4, 3, 12, 12), (4, 3, 3, 3))
    kw = dict(padding=1, schedule="nfft", mesh=_mesh())
    seq = plan_conv(*shp, overlap="off", **kw)
    ovl = plan_conv(*shp, overlap="slab:2", **kw)
    assert seq is not ovl
    assert seq is plan_conv(*shp, overlap="off", **kw)
    assert ovl is plan_conv(*shp, overlap="slab:2", **kw)
    assert f"overlap={ovl.overlap}" in ovl.describe()


# --------------------------------------------------------------------------
# Block resolution against sub-slab shapes (satellite: no per-slab padding)
# --------------------------------------------------------------------------

def test_resolve_blocks_and_bt_respect_slabs():
    from repro.kernels.cgemm.ops import resolve_blocks
    from repro.kernels.dft_tile.ops import resolve_bt
    bm_full, _, _ = resolve_blocks(512, 64, 64)
    bm_slab, _, _ = resolve_blocks(512, 64, 64, slabs=8)
    assert bm_slab <= bm_full
    assert bm_slab <= -(-((512 // 8)) // 8) * 8   # lane-aligned slab fit
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError, match="slabs"):
            resolve_blocks(64, 64, 64, slabs=bad)
    assert resolve_bt(256, slabs=4) <= resolve_bt(256)
    assert resolve_bt(8, 64, slabs=4) <= 8        # explicit bt clamps too
    with pytest.raises(ValueError, match="slabs"):
        resolve_bt(64, slabs=0)


def test_overlap_pallas_blocks_pinned_at_plan_time():
    """fft-pallas overlap plans must carry concrete, slab-fitting blocks
    (pinned once in _resolve) instead of per-call defaults."""
    p = plan_conv((5, 3, 12, 12), (4, 3, 3, 3), padding=1,
                  backend="fft-pallas", schedule="nfft", mesh=_mesh(),
                  overlap="slab:2")
    assert None not in (p.bm, p.bn, p.bk)
    m_min = (5 // 2) * p.spec.n_tiles
    assert p.bm <= -(-m_min // 8) * 8


# --------------------------------------------------------------------------
# Analyzer: overlap invariants + seeded negative path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", SCHEDULES)
def test_analyzer_certifies_overlap(schedule):
    plan = plan_conv((4, 4, 20, 20), (4, 4, 3, 3), padding=1,
                     schedule=schedule, mesh=_mesh(), overlap="slab:2")
    p = analyze(plan)
    assert p.num_slabs == 2 and p.overlap == "slab:2"
    assert "slab:2" in p.describe_key()
    if schedule == "nfft":
        assert p.collectives["all_to_all"] == 4 * 2 + 2   # 4k+2
    else:
        assert p.collectives["psum"] == 2 * 2             # 2k
    # overlapping must not move more bytes than the sequential twin
    assert p.overlap_delta is not None
    assert p.overlap_delta["ratio"] <= 1.005
    p.check().raise_if_failed()
    # prepared overlap still elides exactly the kernel boundary
    prep = analyze(plan.prepare(_rand((4, 4, 3, 3), 9)))
    prep.check().raise_if_failed()
    if schedule == "nfft":
        assert prep.collectives["all_to_all"] == 4 * 2
        assert prep.elision["all_to_all"] == 2


def test_overlap_oversend_violation_is_caught():
    plan = plan_conv((4, 4, 20, 20), (4, 4, 3, 3), padding=1,
                     schedule="nfft", mesh=_mesh(), overlap="slab:2")
    with seeded_violation("overlap-oversend"):
        report = analyze(plan).check()
    assert not report.ok
    assert any(v.invariant == "overlap-bytes-parity"
               for v in report.violations)
    with pytest.raises(AssertionError, match="plan-lint"):
        report.raise_if_failed()
    # the same seed leaves sequential plans untouched (their collectives
    # never route through the slab ops)
    seq = plan_conv((4, 4, 20, 20), (4, 4, 3, 3), padding=1,
                    schedule="nfft", mesh=_mesh(), overlap="off")
    with seeded_violation("overlap-oversend"):
        assert analyze(seq).check().ok


def test_sequential_plans_have_no_overlap_delta():
    p = analyze(plan_conv((4, 4, 20, 20), (4, 4, 3, 3), padding=1,
                          schedule="nfft", mesh=_mesh(), overlap="off"))
    assert p.num_slabs == 1 and p.overlap_delta is None


# --------------------------------------------------------------------------
# Emulated-NUMA meshes (slow: subprocess keeps pytest single-device)
# --------------------------------------------------------------------------

_SCRIPT_MESH = r"""
import os, sys
sys.path.insert(0, {srcpath!r})
from repro.launch import env
env.apply({ndev})
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == {ndev}, jax.device_count()
from repro.compat import make_mesh
mesh = make_mesh({mesh_shape}, ("data", "model"))
from repro.conv import analyze, plan_conv
from repro.core import conv2d_direct
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((9, 8, 20, 20)), jnp.float32)  # odd B
k = jnp.asarray(rng.standard_normal((8, 8, 3, 3)), jnp.float32)
y0 = conv2d_direct(x, k, padding=1)
for sched in ("nfft", "wfft"):
    kw = dict(padding=1, schedule=sched, mesh=mesh)
    seq = plan_conv(x.shape, k.shape, overlap="off", **kw)
    ovl = plan_conv(x.shape, k.shape, overlap="slab:2", **kw)
    ys, yo = jax.jit(seq)(x, k), jax.jit(ovl)(x, k)
    d_seq = float(jnp.max(jnp.abs(yo - ys))) / float(jnp.max(jnp.abs(ys)))
    d_dir = float(jnp.max(jnp.abs(yo - y0))) / float(jnp.max(jnp.abs(y0)))
    assert d_seq < 1e-5, (sched, d_seq)
    assert d_dir < 1e-4, (sched, d_dir)
    p = analyze(ovl)
    assert p.num_slabs == 2
    assert p.overlap_delta["ratio"] <= 1.005, p.overlap_delta
    p.check().raise_if_failed()
print("MESH_OVERLAP_OK", {ndev})
"""


def _run_mesh(ndev, mesh_shape):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT_MESH.format(srcpath=os.path.abspath(src), ndev=ndev,
                                 mesh_shape=mesh_shape)
    r = subprocess.run([sys.executable, "-c", script],
                       env={k: v for k, v in os.environ.items()
                            if k != "XLA_FLAGS"},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert f"MESH_OVERLAP_OK {ndev}" in r.stdout


@pytest.mark.slow
def test_overlap_on_two_way_emulated_mesh():
    _run_mesh(2, (2, 1))


@pytest.mark.slow
def test_overlap_on_four_way_emulated_mesh():
    _run_mesh(4, (2, 2))
