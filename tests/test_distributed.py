"""Distributed tests (subprocess with a forced multi-device host platform,
so the main pytest process keeps its single real device)."""
import os
import subprocess
import sys

import pytest

_SCRIPT_NFFT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, re
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
from repro.conv import plan_conv
from repro.core import conv2d_direct
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 8, 28, 28)), jnp.float32)
k = jnp.asarray(rng.standard_normal((8, 8, 3, 3)), jnp.float32)
y0 = conv2d_direct(x, k, padding=1)
for strat in ("nfft", "wfft"):
    f = jax.jit(plan_conv(x.shape, k.shape, schedule=strat, mesh=mesh,
                          padding=1))
    y = f(x, k)
    err = float(jnp.max(jnp.abs(y - y0))) / float(jnp.max(jnp.abs(y0)))
    assert err < 1e-4, (strat, err)
    hlo = f.lower(x, k).compile().as_text()
    kinds = set(re.findall(
        r"(all-to-all|all-reduce|all-gather|reduce-scatter)", hlo))
    if strat == "nfft":
        assert "all-to-all" in kinds, kinds
        assert "all-reduce" not in kinds, ("nfft must keep the CGEMM "
                                           "collective-free", kinds)
    else:
        assert "all-reduce" in kinds, kinds
# Regression for the replicate_kernel_transform stage-4 Cout (previously a
# dead conditional): with n_model=4 > 1 the replicated path must still
# invert a C'/N output slab per rank and match the oracle.
f = jax.jit(plan_conv(x.shape, k.shape, schedule="nfft", mesh=mesh,
                      padding=1, replicate_kernel_transform=True))
y = f(x, k)
err = float(jnp.max(jnp.abs(y - y0))) / float(jnp.max(jnp.abs(y0)))
assert err < 1e-4, ("nfft_repG", err)
hlo = f.lower(x, k).compile().as_text()
assert "all-reduce" not in hlo, "repG must not introduce an all-reduce"
# the full-spectrum twin must agree with the default compact layout, and
# the compact plan must move at most 0.55x the twin's collective bytes
f = jax.jit(plan_conv(x.shape, k.shape, schedule="nfft", mesh=mesh,
                      padding=1, spectrum="complex"))
y = f(x, k)
err = float(jnp.max(jnp.abs(y - y0))) / float(jnp.max(jnp.abs(y0)))
assert err < 1e-4, ("complex", err)
from repro.conv import analyze
prof = analyze(plan_conv(x.shape, k.shape, schedule="nfft", mesh=mesh,
                         padding=1))
assert prof.spectrum == "real", prof.spectrum
assert prof.spectrum_delta["ratio"] <= 0.55, prof.spectrum_delta
print("DIST_OK")
"""

_SCRIPT_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
from repro.configs import get_config
from repro.optim import AdamWConfig
from repro.train import make_train_step, init_train_state
from repro.launch import shardings as SH
from repro.models.common import ShapeCell
from repro.parallel.act_sharding import activation_sharding
import dataclasses
cfg = get_config("qwen3-14b", smoke=True)
cfg = dataclasses.replace(cfg, n_heads=8, n_kv=4, pad_heads=8, d_model=128,
                          head_dim=16, d_ff=256)
params, opt = init_train_state(cfg, jax.random.PRNGKey(0))
cell = ShapeCell("t", 16, 4, "train")
pspec = SH.named(mesh, SH.param_specs(cfg, params, mesh, fsdp=False))
ospec = {"mu": pspec, "nu": pspec, "step": SH.named(mesh, P())}
bspec = SH.named(mesh, SH.batch_specs(cfg, cell, mesh))
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=5)),
               in_shardings=(pspec, ospec, bspec),
               out_shardings=(pspec, ospec, None))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)))}
with activation_sharding(mesh):
    params, opt, m = step(params, opt, batch)
loss_sharded = float(m["loss"])
# single-device reference
params0, opt0 = init_train_state(cfg, jax.random.PRNGKey(0))
step0 = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=5)))
_, _, m0 = step0(params0, opt0, batch)
assert abs(loss_sharded - float(m0["loss"])) < 1e-2, (loss_sharded,
                                                      float(m0["loss"]))
print("TRAIN_DIST_OK", loss_sharded)
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_nfft_wfft_distributed_correct_and_collective_profile():
    out = _run(_SCRIPT_NFFT)
    assert "DIST_OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = _run(_SCRIPT_TRAIN)
    assert "TRAIN_DIST_OK" in out
