"""Real-input FFT (rfft) half-spectrum fast path.

The compact Hermitian frequency layout (``spectrum="real"``, the planner
default) must agree with the full-spectrum twin (``spectrum="complex"``)
and the direct oracle on every registered backend x schedule pair, for
even AND odd tile sizes (the DC/Nyquist self-conjugate bins differ), and
its plan-level VJP must match the oracle gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, requires_hypothesis

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

from repro.compat import make_mesh
from repro.conv import Epilogue, plan_conv
from repro.conv.registry import backend_schedule_pairs
from repro.core import conv2d_direct
from repro.core.dft import num_freq_full, num_freq_real


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _assert_close(y, y0, tol=2e-4):
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# Parity: real vs complex vs the direct oracle, every backend x schedule
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend,schedule", backend_schedule_pairs())
def test_rfft_parity_every_backend_schedule(backend, schedule):
    mesh = _mesh() if schedule != "local" else None
    x, k = _rand((2, 3, 18, 18), 1), _rand((4, 3, 3, 3), 2)
    y0 = conv2d_direct(x, k, padding=1)
    plan = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                     schedule=schedule, mesh=mesh)
    assert plan.spectrum == "real"             # compact layout is default
    _assert_close(plan(x, k), y0)
    if backend == "direct":
        return                                 # direct has no spectrum
    twin = plan_conv(x.shape, k.shape, padding=1, backend=backend,
                     schedule=schedule, mesh=mesh, spectrum="complex")
    assert twin.spectrum == "complex"
    _assert_close(twin(x, k), y0)


@pytest.mark.parametrize("delta,hw", [(16, 18), (16, 23), (15, 19),
                                      (8, 14), (5, 11)])
@pytest.mark.parametrize("spectrum", ["real", "complex"])
def test_rfft_even_and_odd_tile_sizes(delta, hw, spectrum):
    """Odd delta has NO Nyquist column — the self-conjugate fold weights
    differ from the even case and both layouts must still invert."""
    x, k = _rand((1, 2, hw, hw), 3), _rand((3, 2, 3, 3), 4)
    y0 = conv2d_direct(x, k, padding=1)
    plan = plan_conv(x.shape, k.shape, padding=1, delta=delta,
                     backend="fft-xla", spectrum=spectrum)
    _assert_close(plan(x, k), y0)


def test_rfft_fused_epilogue_parity():
    """fft-pallas/local/real runs stage 4 through the fused irfft+epilogue
    dft_tile kernel — bias and activation must match the oracle."""
    x, k = _rand((2, 3, 18, 18), 5), _rand((4, 3, 3, 3), 6)
    b = _rand((4,), 7)
    y0 = jax.nn.relu(conv2d_direct(x, k, padding=1)
                     + b[None, :, None, None])
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-pallas",
                     epilogue=Epilogue(bias=True, activation="relu"))
    assert plan.spectrum == "real"
    _assert_close(plan(x, k, bias=b), y0)


# --------------------------------------------------------------------------
# Gradients through the plan-level VJP
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spectrum", ["real", "complex"])
def test_rfft_gradients_match_oracle(spectrum):
    x, k = _rand((1, 2, 14, 14), 8), _rand((3, 2, 3, 3), 9)
    plan = plan_conv(x.shape, k.shape, padding=1, backend="fft-xla",
                     spectrum=spectrum)

    def loss(f):
        return lambda a, b: jnp.sum(f(a, b) ** 2)

    gx, gk = jax.grad(loss(plan), argnums=(0, 1))(x, k)
    gx0, gk0 = jax.grad(
        loss(lambda a, b: conv2d_direct(a, b, padding=1)),
        argnums=(0, 1))(x, k)
    _assert_close(gx, gx0, tol=2e-3)
    _assert_close(gk, gk0, tol=2e-3)


# --------------------------------------------------------------------------
# Plan/prepared caching: spectrum is part of the identity
# --------------------------------------------------------------------------

def test_spectrum_is_in_the_plan_cache_key():
    kw = dict(padding=1, backend="fft-xla")
    real = plan_conv((1, 2, 16, 16), (2, 2, 3, 3), **kw)
    real2 = plan_conv((1, 2, 16, 16), (2, 2, 3, 3), **kw, spectrum="real")
    cplx = plan_conv((1, 2, 16, 16), (2, 2, 3, 3), **kw, spectrum="complex")
    assert real is real2                       # "auto" == "real" == default
    assert real is not cplx and cplx.spectrum == "complex"


def test_prepared_state_tracks_spectrum():
    """prepare() bakes the transformed-kernel slab whose P axis depends on
    the layout — a real-prepared state must never serve a complex plan."""
    x, k = _rand((1, 2, 16, 16), 10), _rand((2, 2, 3, 3), 11)
    y0 = conv2d_direct(x, k, padding=1)
    kw = dict(padding=1, backend="fft-xla")
    real = plan_conv(x.shape, k.shape, **kw).prepare(k)
    cplx = plan_conv(x.shape, k.shape, **kw, spectrum="complex").prepare(k)
    p_real = jax.tree_util.tree_leaves(real.state)[0].shape[0]
    p_cplx = jax.tree_util.tree_leaves(cplx.state)[0].shape[0]
    assert p_real == num_freq_real(16) and p_cplx == num_freq_full(16)
    _assert_close(real(x), y0)
    _assert_close(cplx(x), y0)


def test_direct_backend_rejects_complex_spectrum():
    with pytest.raises(ValueError, match="spectrum"):
        plan_conv((1, 2, 16, 16), (2, 2, 3, 3), padding=1,
                  backend="direct", spectrum="complex")
    with pytest.raises(ValueError, match="unknown spectrum"):
        plan_conv((1, 2, 16, 16), (2, 2, 3, 3), padding=1,
                  backend="fft-xla", spectrum="rect")


# --------------------------------------------------------------------------
# Kernel-level parity: Pallas rfft tiles vs the jnp reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [16, 15, 8])
def test_tile_rfft_pallas_matches_ref(delta):
    from repro.kernels.dft_tile import (
        tile_irfft_pallas, tile_irfft_ref, tile_rfft_pallas, tile_rfft_ref,
    )
    x = _rand((7, delta, delta), 12)
    Tr, Ti = tile_rfft_pallas(x, delta=delta, bt=4)
    Tr0, Ti0 = tile_rfft_ref(x, delta)
    assert Tr.shape == (7, num_freq_real(delta))
    _assert_close(Tr, Tr0, tol=1e-4)
    _assert_close(Ti, Ti0, tol=1e-4)
    y = tile_irfft_pallas(Tr, Ti, delta=delta, bt=4)
    _assert_close(y, x, tol=1e-4)
    _assert_close(tile_irfft_ref(Tr0, Ti0, delta), x, tol=1e-4)


def test_tile_irfft_pallas_ignores_trailing_padding():
    """nfft pads the P axis for all-to-all divisibility; the inverse must
    treat rows past num_freq_real as inert."""
    from repro.kernels.dft_tile import tile_irfft_pallas, tile_rfft_pallas
    x = _rand((5, 16, 16), 13)
    Tr, Ti = tile_rfft_pallas(x, delta=16)
    pad = ((0, 0), (0, 6))
    yp = tile_irfft_pallas(jnp.pad(Tr, pad) + 0,
                           jnp.pad(Ti, pad) + 0, delta=16)
    _assert_close(yp, x, tol=1e-4)


def test_tile_irfft_epilogue_pallas_fuses_bias_relu():
    from repro.kernels.dft_tile import (
        tile_irfft_epilogue_pallas, tile_irfft_ref, tile_rfft_pallas,
    )
    x = _rand((6, 16, 16), 14)
    b = _rand((6,), 15)
    Tr, Ti = tile_rfft_pallas(x, delta=16)
    y = tile_irfft_epilogue_pallas(Tr, Ti, b, activation="relu", delta=16)
    y0 = jax.nn.relu(tile_irfft_ref(Tr, Ti, 16) + b[:, None, None])
    _assert_close(y, y0, tol=1e-4)


# --------------------------------------------------------------------------
# Property: random geometries (hypothesis)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @requires_hypothesis
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 2), st.integers(1, 3), st.integers(1, 3),
           st.integers(6, 24), st.integers(6, 24),
           st.sampled_from([1, 3, 5]), st.integers(0, 2),
           st.sampled_from([16, 15, 8]))
    def test_rfft_random_geometry(B, C, Co, H, W, ksz, pad, delta):
        x = _rand((B, C, H, W), H * W + ksz)
        k = _rand((Co, C, ksz, ksz), H + W)
        y0 = conv2d_direct(x, k, padding=pad)
        y = plan_conv(x.shape, k.shape, padding=pad, delta=delta,
                      backend="fft-xla")(x, k)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=5e-4, atol=5e-4)
