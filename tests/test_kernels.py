"""Per-kernel allclose vs pure-jnp oracles (interpret mode on CPU),
sweeping shapes and dtypes per the deliverable."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cgemm import cgemm_pallas, cgemm_ref
from repro.kernels.dft_tile import (tile_fft_pallas, tile_ifft_pallas,
                                    tile_fft_ref, tile_ifft_ref)


def _r(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       dtype)


@pytest.mark.parametrize("P,M,C,N", [
    (4, 128, 128, 128), (3, 200, 67, 130), (2, 16, 3, 5),
    (1, 256, 64, 256), (9, 32, 512, 64),
])
@pytest.mark.parametrize("three_m", [True, False])
def test_cgemm_shapes(P, M, C, N, three_m):
    Dr, Di = _r((P, M, C), 1), _r((P, M, C), 2)
    Gr, Gi = _r((P, C, N), 3), _r((P, C, N), 4)
    Zr0, Zi0 = cgemm_ref(Dr, Di, Gr, Gi)
    Zr, Zi = cgemm_pallas(Dr, Di, Gr, Gi, three_m=three_m)
    scale = float(jnp.max(jnp.abs(Zr0))) + 1e-9
    np.testing.assert_allclose(np.asarray(Zr) / scale,
                               np.asarray(Zr0) / scale, atol=2e-5)
    np.testing.assert_allclose(np.asarray(Zi) / scale,
                               np.asarray(Zi0) / scale, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cgemm_dtypes(dtype):
    Dr, Di = _r((2, 64, 32), 5, dtype), _r((2, 64, 32), 6, dtype)
    Gr, Gi = _r((2, 32, 48), 7, dtype), _r((2, 32, 48), 8, dtype)
    Zr, Zi = cgemm_pallas(Dr, Di, Gr, Gi)
    Zr0, Zi0 = cgemm_ref(Dr.astype(jnp.float32), Di.astype(jnp.float32),
                         Gr.astype(jnp.float32), Gi.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    scale = float(jnp.max(jnp.abs(Zr0))) + 1e-9
    np.testing.assert_allclose(np.asarray(Zr, np.float32) / scale,
                               np.asarray(Zr0) / scale, atol=tol)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (128, 64, 16), (64, 128, 128)])
def test_cgemm_block_sweep(blocks):
    bm, bn, bk = blocks
    Dr, Di = _r((3, 96, 48), 9), _r((3, 96, 48), 10)
    Gr, Gi = _r((3, 48, 80), 11), _r((3, 48, 80), 12)
    Zr0, Zi0 = cgemm_ref(Dr, Di, Gr, Gi)
    Zr, Zi = cgemm_pallas(Dr, Di, Gr, Gi, bm=bm, bn=bn, bk=bk)
    scale = float(jnp.max(jnp.abs(Zr0))) + 1e-9
    np.testing.assert_allclose(np.asarray(Zr) / scale,
                               np.asarray(Zr0) / scale, atol=2e-5)


@pytest.mark.parametrize("n,delta,bt", [
    (7, 16, 4), (256, 16, 64), (5, 8, 8), (33, 32, 16), (1, 16, 1),
])
def test_tile_fft_roundtrip(n, delta, bt):
    x = _r((n, delta, delta), n)
    Tr, Ti = tile_fft_pallas(x, delta=delta, bt=bt)
    Tr0, Ti0 = tile_fft_ref(x, delta)
    np.testing.assert_allclose(np.asarray(Tr), np.asarray(Tr0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(Ti), np.asarray(Ti0),
                               rtol=1e-4, atol=1e-4)
    y = tile_ifft_pallas(Tr, Ti, delta=delta, bt=bt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


def test_tile_fft_vs_numpy():
    x = _r((6, 16, 16), 42)
    Tr, Ti = tile_fft_ref(x, 16)
    ref = np.fft.rfft2(np.asarray(x))
    np.testing.assert_allclose(np.asarray(Tr), ref.real, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Ti), ref.imag, rtol=1e-4,
                               atol=1e-4)


def test_default_blocks_round_to_lane_friendly():
    """Small dims round UP to a power-of-two edge in {8..128} (operands are
    zero-padded to block multiples), never a degenerate raw-dim block."""
    from repro.kernels.cgemm.ops import _default_blocks
    assert _default_blocks(3, 5, 130) == (8, 8, 128)     # C=3 conv1.1 case
    assert _default_blocks(16, 64, 128) == (16, 64, 128)
    assert _default_blocks(200, 9, 33) == (128, 16, 64)
    assert all(b in (8, 16, 32, 64, 128)
               for b in _default_blocks(1, 7, 1000))


def test_cgemm_tiny_dims_use_rounded_blocks():
    """C=3-style degenerate dims still produce correct numerics through the
    rounded default blocks."""
    Dr, Di = _r((2, 12, 3), 21), _r((2, 12, 3), 22)
    Gr, Gi = _r((2, 3, 5), 23), _r((2, 3, 5), 24)
    Zr0, Zi0 = cgemm_ref(Dr, Di, Gr, Gi)
    Zr, Zi = cgemm_pallas(Dr, Di, Gr, Gi)
    np.testing.assert_allclose(np.asarray(Zr), np.asarray(Zr0), atol=2e-5)
    np.testing.assert_allclose(np.asarray(Zi), np.asarray(Zi0), atol=2e-5)


@pytest.mark.parametrize("activation", ["none", "relu", "gelu", "silu"])
def test_tile_ifft_epilogue_matches_composed(activation):
    """The fused inverse+epilogue kernel == unfused inverse, then bias+act
    (elementwise-before-crop equals crop-then-elementwise on kept elems)."""
    import jax
    from repro.kernels.dft_tile import tile_ifft_epilogue_pallas
    n, delta = 6, 16
    x = _r((n, delta, delta), 31)
    Tr, Ti = tile_fft_ref(x, delta)
    bias = _r((n,), 32)
    y = tile_ifft_epilogue_pallas(Tr, Ti, bias, activation=activation,
                                  delta=delta)
    from repro.conv.epilogue import ACTIVATIONS
    y0 = ACTIVATIONS[activation](
        tile_ifft_pallas(Tr, Ti, delta=delta) + bias[:, None, None])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
